#!/usr/bin/env python3
"""Toolchain-free mirror of `cargo xtask lint` / `cargo xtask fixtures`.

This is a line-for-line port of the Rust analysis pipeline in `xtask/src/`
(scan -> lexer -> item tree -> call graph -> lint passes) so that containers
without a Rust toolchain can still verify the tree and the fixture corpus.
The two implementations MUST produce identical findings (file, line, rule)
on every fixture under `xtask/fixtures/` — `cargo xtask fixtures
--emit-findings` and `lint_mirror.py fixtures --emit-findings` print the
same canonical lines, and the xtask unit test `mirror_agrees_on_fixtures`
(plus the `lint-mirror` CI pre-job) diff them.

Usage:
    python3 tools/lint_mirror.py lint     [--format human|json|sarif]
    python3 tools/lint_mirror.py fixtures [--emit-findings]

Exit codes: 0 = clean / all fixtures behave, 1 = findings or failures.

Keep this file in lockstep with `xtask/src/{scan,lexer,items,callgraph,
units,lints,main}.rs`. DESIGN.md §9 documents the shared architecture.
"""

import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

RULES = [
    "accounting-fields",
    "lossy-casts",
    "safety-comments",
    "hot-path-panics",
    "simd-gating",
    "hot-path-alloc",
    "unit-confusion",
    "sendptr-escape",
    "dispatch-parity-drift",
    "lock-order",
    "condvar-discipline",
    "atomic-ordering",
    "channel-lifecycle",
]

# Cross-artifact inputs consumed by the whole-program lints. In repo mode
# they are read from disk; in fixture mode a `//=== file: <path>` section
# with one of these paths overrides them (absent section = empty artifact).
AUX_MIRI = "rust/tests/miri_kernels.rs"
AUX_PARITY = "rust/tests/kernel_parity_test.rs"
AUX_DESIGN = "DESIGN.md"
AUX_PATHS = (AUX_MIRI, AUX_PARITY, AUX_DESIGN)


def is_ident_char(c):
    return c == "_" or c.isascii() and c.isalnum()


# --- scan: comment/string masking + cfg span marking (port of scan.rs) ----


class Scanned:
    __slots__ = ("masked", "comments", "lines", "test_lines", "simd_lines")


def _find_from(hay, needle, from_):
    p = hay.find(needle, from_)
    return None if p < 0 else p


def _match_delim(s, open_pos, op, cl):
    depth = 0
    j = open_pos
    n = len(s)
    while j < n:
        if s[j] == op:
            depth += 1
        elif s[j] == cl:
            depth -= 1
            if depth == 0:
                return j
        j += 1
    return max(n - 1, 0)


def _line_of(masked, byte_off):
    return masked.count("\n", 0, byte_off) + 1


def _is_raw_string_start(s, i):
    j = i
    if s[j] == "b":
        j += 1
    if j >= len(s) or s[j] != "r":
        return False
    j += 1
    while j < len(s) and s[j] == "#":
        j += 1
    return j < len(s) and s[j] == '"'


def _skip_raw_string(s, i):
    j = i
    if s[j] == "b":
        j += 1
    j += 1  # 'r'
    hashes = 0
    while j < len(s) and s[j] == "#":
        hashes += 1
        j += 1
    j += 1  # opening quote
    while True:
        if j >= len(s):
            return len(s)
        if s[j] == '"':
            h = 0
            while j + 1 + h < len(s) and s[j + 1 + h] == "#" and h < hashes:
                h += 1
            if h == hashes:
                return j + 1 + hashes
        j += 1


def _skip_string(s, i):
    j = i + 1
    while j < len(s):
        c = s[j]
        if c == "\\":
            j += 2
        elif c == '"':
            return j + 1
        else:
            j += 1
    return len(s)


def scan(src):
    n = len(src)
    out = []
    comments = {}
    line = 1
    i = 0

    def mask_into(chunk):
        nonlocal line
        for ch in chunk:
            if ch == "\n":
                out.append("\n")
                line += 1
            else:
                out.append(" ")

    while i < n:
        c = src[i]
        nx = src[i + 1] if i + 1 < n else "\0"
        if c == "\n":
            out.append("\n")
            line += 1
            i += 1
        elif c == "/" and nx == "/":
            j = i
            while j < n and src[j] != "\n":
                j += 1
            comments[line] = comments.get(line, "") + src[i:j]
            mask_into(src[i:j])
            i = j
        elif c == "/" and nx == "*":
            start_line = line
            depth = 1
            j = i + 2
            while j < n and depth > 0:
                if src[j] == "/" and j + 1 < n and src[j + 1] == "*":
                    depth += 1
                    j += 2
                elif src[j] == "*" and j + 1 < n and src[j + 1] == "/":
                    depth -= 1
                    j += 2
                else:
                    j += 1
            comments[start_line] = comments.get(start_line, "") + src[i:j]
            mask_into(src[i:j])
            i = j
        elif c in ("r", "b") and _is_raw_string_start(src, i):
            j = _skip_raw_string(src, i)
            mask_into(src[i:j])
            i = j
        elif c == '"':
            j = _skip_string(src, i)
            mask_into(src[i:j])
            i = j
        elif c == "b" and nx == '"':
            j = _skip_string(src, i + 1)
            mask_into(src[i:j])
            i = j
        elif c == "'":
            if nx == "\\":
                j = i + 2
                while j < n and src[j] != "'" and src[j] != "\n":
                    j += 1
                if j < n and src[j] == "'":
                    j += 1
                mask_into(src[i:j])
                i = j
            elif i + 2 < n and src[i + 2] == "'":
                out.append("   ")
                i += 3
            else:
                out.append(" ")
                i += 1
        else:
            out.append(c if ord(c) < 0x80 else " ")
            i += 1

    s = Scanned()
    s.masked = "".join(out)
    s.comments = comments
    s.lines = s.masked.split("\n")
    s.test_lines = _mark_spans(s.masked, s.masked, len(s.lines), "#[cfg(test)]", None)
    s.simd_lines = _mark_spans(s.masked, src, len(s.lines), "#[cfg(", "simd")
    return s


def _mark_spans(masked, raw, n_lines, needle, feature):
    """Shared body of mark_test_lines / mark_simd_lines (scan.rs)."""
    marks = [False] * (n_lines + 2)
    from_ = 0
    while True:
        pos = _find_from(masked, needle, from_)
        if pos is None:
            break
        from_ = pos + len(needle)
        if feature is not None:
            open_paren = pos + len(needle) - 1
            close_paren = _match_delim(masked, open_paren, "(", ")")
            pred = raw[open_paren : min(close_paren, len(raw))]
            if "feature" not in pred or feature not in pred:
                continue
            j = close_paren
        else:
            j = from_
        open_b = None
        semi = None
        while j < len(masked):
            ch = masked[j]
            if ch == "{":
                open_b = j
                break
            if ch == ";":
                semi = j
                break
            j += 1
        if open_b is not None:
            end = _match_delim(masked, open_b, "{", "}")
        elif feature is not None and semi is not None:
            end = semi
        else:
            continue
        l0 = _line_of(masked, pos)
        l1 = _line_of(masked, min(end, max(len(masked) - 1, 0)))
        for ln in range(l0, min(l1, n_lines) + 1):
            marks[ln] = True
    return [marks[ln] for ln in range(1, n_lines + 1)]


# --- lexer (port of lexer.rs) ---------------------------------------------

OPS3 = ["..=", "<<=", ">>="]
OPS2 = [
    "::", "->", "=>", "..", "+=", "-=", "*=", "/=", "%=",
    "==", "!=", "<=", ">=", "&&", "||", "<<", ">>",
]


def lex(masked):
    """Tokenize a masked source: (text, line) pairs, 1-based lines."""
    toks = []
    i = 0
    line = 1
    n = len(masked)
    while i < n:
        c = masked[i]
        if c == "\n":
            line += 1
            i += 1
        elif c in " \t\r":
            i += 1
        elif is_ident_char(c):
            j = i
            while j < n and is_ident_char(masked[j]):
                j += 1
            toks.append((masked[i:j], line))
            i = j
        else:
            three = masked[i : i + 3]
            two = masked[i : i + 2]
            if three in OPS3:
                toks.append((three, line))
                i += 3
            elif two in OPS2:
                toks.append((two, line))
                i += 2
            else:
                toks.append((c, line))
                i += 1
    return toks


def tok_is_ident(text):
    return bool(text) and is_ident_char(text[0]) and not text[0].isdigit()


# --- item tree (port of items.rs) -----------------------------------------


class FnItem:
    __slots__ = ("name", "ctx", "mods", "sig_line", "body", "end_line",
                 "is_test", "is_simd")


class StructItem:
    __slots__ = ("name", "line", "fields", "is_test")


def _skip_angle(toks, i):
    """toks[i] == '<': index just past the matching '>'. Fail-safe: on '{'
    or ';' or exhaustion, give up and return i + 1 (callers re-scan)."""
    depth = 0
    j = i
    n = len(toks)
    while j < n:
        t = toks[j][0]
        if t == "<":
            depth += 1
        elif t == "<<":
            depth += 2
        elif t == ">":
            depth -= 1
            if depth == 0:
                return j + 1
        elif t == ">>":
            depth -= 2
            if depth <= 0:
                return j + 1
        elif t in ("{", ";"):
            return i + 1
        j += 1
    return i + 1


def _match_brace_toks(toks, i):
    """toks[i] == '{': index of the matching '}' (fail-safe: last token)."""
    depth = 0
    j = i
    n = len(toks)
    while j < n:
        t = toks[j][0]
        if t == "{":
            depth += 1
        elif t == "}":
            depth -= 1
            if depth == 0:
                return j
        j += 1
    return n - 1


def _match_paren_toks(toks, i):
    depth = 0
    j = i
    n = len(toks)
    while j < n:
        t = toks[j][0]
        if t == "(":
            depth += 1
        elif t == ")":
            depth -= 1
            if depth == 0:
                return j
        j += 1
    return n - 1


def _match_bracket_toks(toks, i):
    depth = 0
    j = i
    n = len(toks)
    while j < n:
        t = toks[j][0]
        if t == "[":
            depth += 1
        elif t == "]":
            depth -= 1
            if depth == 0:
                return j
        j += 1
    return n - 1


def parse_items(toks, scanned):
    """One walker pass: fns (with impl/trait ctx + mod path), structs, and
    the set of trait-declared method names (used for dynamic-dispatch
    over-approximation in the call graph).

    Fn bodies are consumed whole (nested item defs inside a body are
    attributed to the enclosing fn — correct for reachability, since a
    nested fn is only callable from its parent)."""
    fns = []
    structs = []
    trait_methods = set()
    scopes = []  # ("impl"|"trait"|"mod"|"block", name-or-None)
    n = len(toks)
    i = 0

    def line_flag(flags, ln):
        idx = ln - 1
        return flags[idx] if 0 <= idx < len(flags) else False

    while i < n:
        t, ln = toks[i]
        if t == "{":
            scopes.append(("block", None))
            i += 1
        elif t == "}":
            if scopes:
                scopes.pop()
            i += 1
        elif t in ("impl", "trait"):
            j = i + 1
            if t == "trait":
                # `trait Name` — supertrait bounds may follow; name first.
                name = toks[j][0] if j < n and tok_is_ident(toks[j][0]) else None
                while j < n and toks[j][0] not in ("{", ";"):
                    if toks[j][0] == "<":
                        j = _skip_angle(toks, j)
                    else:
                        j += 1
            else:
                if j < n and toks[j][0] == "<":
                    j = _skip_angle(toks, j)
                name = None
                while j < n and toks[j][0] not in ("{", ";"):
                    tj = toks[j][0]
                    if tj == "<":
                        j = _skip_angle(toks, j)
                    elif tj == "for":
                        name = None
                        j += 1
                    elif tok_is_ident(tj):
                        name = tj
                        j += 1
                    else:
                        j += 1
            if j < n and toks[j][0] == "{":
                scopes.append(("trait" if t == "trait" else "impl", name))
                i = j + 1
            else:
                i = j + 1
        elif t == "mod" and i + 1 < n and tok_is_ident(toks[i + 1][0]):
            if i + 2 < n and toks[i + 2][0] == "{":
                scopes.append(("mod", toks[i + 1][0]))
                i += 3
            else:
                i += 2
        elif t == "struct" and i + 1 < n and tok_is_ident(toks[i + 1][0]):
            sname, sline = toks[i + 1]
            j = i + 2
            if j < n and toks[j][0] == "<":
                j = _skip_angle(toks, j)
            if j < n and toks[j][0] == "{":
                close = _match_brace_toks(toks, j)
                fields = []
                k = j + 1
                while k < close:
                    tk = toks[k][0]
                    if tk in ("(", "["):
                        k = (_match_paren_toks if tk == "(" else _match_bracket_toks)(toks, k) + 1
                        continue
                    if tk == "{":
                        k = _match_brace_toks(toks, k) + 1
                        continue
                    if (
                        tok_is_ident(tk)
                        and k + 1 < close
                        and toks[k + 1][0] == ":"
                        and (k == j + 1 or toks[k - 1][0] in (",", "{", ")") or toks[k - 1][0] == "pub")
                    ):
                        first_ty = toks[k + 2][0] if k + 2 < close else ""
                        fields.append((tk, toks[k][1], first_ty))
                        k += 2
                        continue
                    k += 1
                st = StructItem()
                st.name = sname
                st.line = sline
                st.fields = fields
                st.is_test = line_flag(scanned.test_lines, sline)
                structs.append(st)
                i = close + 1
            else:
                # tuple / unit struct: skip to `;`
                while j < n and toks[j][0] != ";":
                    j += 1
                i = j + 1
        elif t == "fn" and i + 1 < n and tok_is_ident(toks[i + 1][0]):
            name = toks[i + 1][0]
            j = i + 2
            if j < n and toks[j][0] == "<":
                j = _skip_angle(toks, j)
            while j < n and toks[j][0] != "(":
                j += 1
            j = _match_paren_toks(toks, j)
            k = j + 1
            while k < n and toks[k][0] not in ("{", ";"):
                k += 1
            in_trait = any(kind == "trait" for kind, _ in scopes)
            if in_trait:
                trait_methods.add(name)
            if k >= n or toks[k][0] == ";":
                i = k + 1
                continue
            close = _match_brace_toks(toks, k)
            f = FnItem()
            f.name = name
            f.ctx = next(
                (nm for kind, nm in reversed(scopes) if kind in ("impl", "trait")),
                None,
            )
            f.mods = [nm for kind, nm in scopes if kind == "mod"]
            f.sig_line = ln
            f.body = (k + 1, close)  # token range, exclusive of braces
            f.end_line = toks[close][1]
            f.is_test = line_flag(scanned.test_lines, ln)
            f.is_simd = line_flag(scanned.simd_lines, ln)
            fns.append(f)
            i = close + 1
        else:
            i += 1
    return fns, structs, trait_methods


# --- annotations ----------------------------------------------------------


def lint_ok(scanned, line, rule):
    """`// lint-ok(<rule>): <reason>` on the line or the line above."""
    needle = "lint-ok(" + rule + ")"
    for ln in (line, line - 1):
        if needle in scanned.comments.get(ln, ""):
            return True
    return False


class Sink:
    """Finding sink with lint-ok suppression + counting."""

    def __init__(self):
        self.findings = []
        self.suppressed = 0

    def emit(self, scanned, rel, line, rule, msg, force_ok=False):
        if force_ok or lint_ok(scanned, line, rule):
            self.suppressed += 1
            return
        self.findings.append({"file": rel, "line": line, "rule": rule, "msg": msg})


# --- per-file lints (ports of the PR-6/7 rules) ---------------------------

ACCOUNTING_FIELDS = ["used_bytes", "cold_bytes", "outstanding"]
FLAGGED_CASTS = ["u8", "u16", "u32", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize"]
CAST_SCOPE = ["rust/src/kvcache/", "rust/src/coordinator/", "rust/src/server/", "rust/src/config/"]
PANIC_MACROS = ["panic!", "unreachable!", "todo!", "unimplemented!"]
INTRINSIC_MARKERS = ["core::arch", "std::arch::x86_64", "std::arch::aarch64", "#[target_feature"]


def word_positions(line, word):
    out = []
    from_ = 0
    while True:
        p = line.find(word, from_)
        if p < 0:
            return out
        from_ = p + 1
        pre_ok = not is_ident_char(word[0]) or p == 0 or not is_ident_char(line[p - 1])
        end = p + len(word)
        post_ok = (
            not is_ident_char(word[-1]) or end >= len(line) or not is_ident_char(line[end])
        )
        if pre_ok and post_ok:
            out.append(p)


def next_non_space(line, from_):
    for c in line[from_:]:
        if not c.isspace():
            return c
    return None


def in_test(s, line):
    idx = line - 1
    return s.test_lines[idx] if 0 <= idx < len(s.test_lines) else False


def comment_on(s, line, needle):
    return needle in s.comments.get(line, "")


def fn_spans(s, name):
    """1-based inclusive line spans of every `fn <name>` body (scan.rs)."""
    masked = s.masked
    spans = []
    from_ = 0
    while True:
        pos = _find_from(masked, "fn ", from_)
        if pos is None:
            return spans
        from_ = pos + 3
        if pos > 0 and is_ident_char(masked[pos - 1]):
            continue
        j = pos + 3
        while j < len(masked) and masked[j] == " ":
            j += 1
        id_start = j
        while j < len(masked) and is_ident_char(masked[j]):
            j += 1
        if masked[id_start:j] != name:
            continue
        k = j
        open_b = None
        while k < len(masked):
            if masked[k] == "{":
                open_b = k
                break
            if masked[k] == ";":
                break
            k += 1
        if open_b is None:
            continue
        close = _match_delim(masked, open_b, "{", "}")
        spans.append((_line_of(masked, pos), _line_of(masked, close)))


def lint_accounting_fields(rel, s, sink):
    if rel.startswith("rust/src/kvcache/"):
        return
    for i, line in enumerate(s.lines):
        for field in ACCOUNTING_FIELDS:
            dotted = "." + field
            for p in word_positions(line, dotted):
                if next_non_space(line, p + len(dotted)) == "(":
                    continue
                sink.emit(
                    s, rel, i + 1, "accounting-fields",
                    "raw access to accounting field `%s` outside kvcache "
                    "(use the accessor / counter API audited by verify_accounting)" % field,
                )


def lint_lossy_casts(rel, s, sink):
    if not any(rel.startswith(p) for p in CAST_SCOPE):
        return
    for i, line in enumerate(s.lines):
        ln = i + 1
        if in_test(s, ln):
            continue
        for p in word_positions(line, "as"):
            rest = line[p + 2 :].lstrip()
            ty = ""
            for c in rest:
                if is_ident_char(c):
                    ty += c
                else:
                    break
            if ty not in FLAGGED_CASTS:
                continue
            if comment_on(s, ln, "cast-ok:"):
                continue
            sink.emit(
                s, rel, ln, "lossy-casts",
                "narrowing `as %s` in accounting path — use u64-native math, "
                "`try_from`, or justify with `// cast-ok: <reason>`" % ty,
            )


def lint_safety_comments(rel, s, sink):
    for i, line in enumerate(s.lines):
        ln = i + 1
        for p in word_positions(line, "unsafe"):
            rest = line[p + len("unsafe") :].lstrip()
            if not (rest.startswith("{") or rest.startswith("impl")):
                continue
            if comment_on(s, ln, "SAFETY:"):
                continue
            found = False
            k = ln - 1
            while k >= 1:
                if comment_on(s, k, "SAFETY:"):
                    found = True
                    break
                stripped = s.lines[k - 1].strip()
                if stripped and not stripped.startswith("#["):
                    if (
                        stripped.endswith(";")
                        or stripped.endswith("}")
                        or stripped.endswith("{")
                        or stripped.endswith(")")
                    ):
                        break
                elif not stripped and k not in s.comments:
                    break
                k -= 1
            if not found:
                sink.emit(
                    s, rel, ln, "safety-comments",
                    "unsafe block/impl without a preceding `// SAFETY:` comment",
                )


def lint_hot_path_panics(rel, s, sink):
    hot = [False] * len(s.lines)
    if rel == "rust/src/coordinator/batcher.rs":
        for i in range(len(hot)):
            hot[i] = not in_test(s, i + 1)
    if rel == "rust/src/coordinator/mod.rs":
        for a, b in fn_spans(s, "pump"):
            for ln in range(a, min(b, len(s.lines)) + 1):
                hot[ln - 1] = True
    for a, b in fn_spans(s, "step_fused"):
        if in_test(s, a):
            continue
        for ln in range(a, min(b, len(s.lines)) + 1):
            hot[ln - 1] = True
    for i, line in enumerate(s.lines):
        if not hot[i]:
            continue
        for meth in ("unwrap", "expect"):
            dotted = "." + meth
            for p in word_positions(line, dotted):
                if next_non_space(line, p + len(dotted)) == "(":
                    sink.emit(
                        s, rel, i + 1, "hot-path-panics",
                        "`.%s(..)` in the serving hot path — route the error "
                        "to TokenEvent::Rejected / anyhow::Result instead" % meth,
                    )
        for mac in PANIC_MACROS:
            bare = mac[:-1]
            for p in word_positions(line, bare):
                if line[p + len(bare) :].startswith("!"):
                    sink.emit(
                        s, rel, i + 1, "hot-path-panics",
                        "`%s` in the serving hot path" % mac,
                    )


def lint_simd_gating(rel, s, sink):
    any_intrinsics = False
    for i, line in enumerate(s.lines):
        marker = next((m for m in INTRINSIC_MARKERS if m in line), None)
        if marker is None:
            continue
        any_intrinsics = True
        if 0 <= i < len(s.simd_lines) and s.simd_lines[i]:
            continue
        sink.emit(
            s, rel, i + 1, "simd-gating",
            '`%s` outside a `#[cfg(.. feature = "simd" ..)]`-gated item — '
            "scalar-only builds (--no-default-features, Miri) must not compile intrinsics"
            % marker,
        )
    if any_intrinsics and "_feature_detected!" not in s.masked:
        sink.emit(
            s, rel, 1, "simd-gating",
            "file uses arch intrinsics but contains no runtime `*_feature_detected!` "
            "check — compiling an ISA arm must never imply executing it",
        )


# --- call graph (port of callgraph.rs) ------------------------------------

HOT_ROOTS = (
    ("step", "Batcher"),
    ("step_fused", None),
    ("decode", "ServingEngine"),
    # The fleet dispatcher's per-submission routing decision (reads
    # caller-built load snapshots precisely so it can stay allocation- and
    # lock-free).
    ("route_request", "FleetDispatch"),
)


# Method names that collide with std-prelude methods: a `.name(` call on an
# unknown receiver must NOT resolve intra-crate through these — `.clone()` on
# a String would otherwise edge into any crate type's `clone`, and `.err()`
# on a Result would edge into `Parser::err`. (Qualified `Type::name(..)`
# calls still resolve normally.)
METHOD_EDGE_DENY = {
    "clone", "to_vec", "to_string", "to_owned", "collect", "expect",
    "unwrap", "unwrap_or", "unwrap_or_else", "unwrap_or_default", "into",
    "from", "try_from", "try_into", "default", "new", "len", "is_empty",
    "iter", "iter_mut", "into_iter", "push", "pop", "insert", "remove",
    "get", "get_mut", "contains", "contains_key", "map", "map_err",
    "and_then", "or_else", "ok", "err", "ok_or", "ok_or_else", "as_ref",
    "as_mut", "as_slice", "as_str", "parse", "min", "max", "abs", "clamp",
    "fmt", "eq", "cmp", "partial_cmp", "hash", "next", "extend", "clear",
    "drain", "take", "replace", "write", "read", "flush", "send", "recv",
    "lock", "borrow", "borrow_mut", "join", "spawn", "wait", "drop",
}


def call_edges(toks, fn):
    """(callee, kind, qualifier, line, tok_idx) call sites in the fn body.

    kind: "free"      — bare `name(..)` (incl. `self::`/`crate::`/`super::`)
          "qualified" — `Qual::name(..)` with `Self` mapped to the caller ctx
          "method"    — `recv.name(..)`; qualifier is the receiver token
    """
    edges = []
    start, end = fn.body
    i = start
    while i < end:
        t, ln = toks[i]
        if tok_is_ident(t):
            k = i + 1
            if k < end and toks[k][0] == "::" and k + 1 < end and toks[k + 1][0] == "<":
                k = _skip_angle(toks, k + 1)
            if k < end and toks[k][0] == "(":
                prev = toks[i - 1][0] if i > 0 else ""
                if prev == "fn":
                    i += 1
                    continue
                if prev == ".":
                    recv = toks[i - 2][0] if i >= 2 else ""
                    edges.append((t, "method", recv, ln, i))
                elif prev == "::" and i >= 2 and tok_is_ident(toks[i - 2][0]):
                    q = toks[i - 2][0]
                    if q == "Self" and fn.ctx:
                        edges.append((t, "qualified", fn.ctx, ln, i))
                    elif q in ("self", "crate", "super", "Self"):
                        edges.append((t, "free", None, ln, i))
                    else:
                        edges.append((t, "qualified", q, ln, i))
                else:
                    edges.append((t, "free", None, ln, i))
        i += 1
    return edges


def file_mod_path(rel):
    """Module path segments a file contributes (rust/src/attn/mod.rs →
    ["attn"], rust/src/coordinator/batcher.rs → ["coordinator", "batcher"]).
    Fixture paths outside rust/src get their bare stem."""
    parts = rel.replace("\\", "/").split("/")
    if parts[:2] == ["rust", "src"]:
        parts = parts[2:]
    else:
        parts = parts[-1:]
    if parts and parts[-1].endswith(".rs"):
        parts[-1] = parts[-1][: -len(".rs")]
    if parts and parts[-1] in ("mod", "lib", "main"):
        parts = parts[:-1]
    return parts


class CrateModel:
    def __init__(self, files, aux, trait_methods, field_types, struct_names):
        # files: list of dicts {rel, src, scanned, toks, fns, structs}
        self.files = files
        self.aux = aux
        self.trait_methods = trait_methods  # names declared in any trait
        self.field_types = field_types  # struct name -> {field -> first ty tok}
        self.struct_names = struct_names

    @staticmethod
    def build(file_pairs, aux):
        files = []
        trait_methods = set()
        field_types = {}
        struct_names = set()
        for rel, src in file_pairs:
            s = scan(src)
            toks = lex(s.masked)
            fns, structs, traits = parse_items(toks, s)
            mod_path = file_mod_path(rel)
            for fn in fns:
                fn.mods = mod_path + fn.mods
            trait_methods |= traits
            for st in structs:
                struct_names.add(st.name)
                field_types.setdefault(st.name, {}).update(
                    {fname: fty for fname, _, fty in st.fields}
                )
            files.append(
                {"rel": rel, "src": src, "scanned": s, "toks": toks,
                 "fns": fns, "structs": structs}
            )
        return CrateModel(files, aux, trait_methods, field_types, struct_names)


def fn_label(fn):
    return (fn.ctx + "::" + fn.name) if fn.ctx else fn.name


def build_call_index(model):
    """(nodes, {name: [(file_idx, fn_idx)]}) over non-test fns — the shared
    substrate for every call-graph-driven pass (reachability, concurrency)."""
    index = {}
    nodes = []
    for fi, f in enumerate(model.files):
        for gi, fn in enumerate(f["fns"]):
            if fn.is_test:
                continue
            nodes.append((fi, gi))
            index.setdefault(fn.name, []).append((fi, gi))
    return nodes, index


def resolve_call(model, index, name, kind, qual, caller_ctx):
    """Resolution ladder shared by reachability and the concurrency stage,
    most precise first:
      1. `self.name(..)` → the caller's own impl.
      2. `self.field.name(..)` / `field.name(..)` where the caller's
         struct declares `field: Ty` and `Ty` is a crate struct → Ty's
         impl (precise even for std-colliding names like `insert`).
      3. std-prelude collisions (METHOD_EDGE_DENY) → no edge.
      4. trait-declared names → ALL same-named fns (dynamic dispatch:
         over-approximation is the conservative answer).
      5. otherwise → edge only if the name is crate-unique; an
         ambiguous name would fan one `.load(..)` into every `load`.
    """
    cands = index.get(name, [])
    if kind == "qualified":
        out = []
        for fi, gi in cands:
            fn = model.files[fi]["fns"][gi]
            if fn.ctx == qual or qual in fn.mods:
                out.append((fi, gi))
        return out
    if kind == "free":
        # Single-letter names are overwhelmingly closure/fn-pointer
        # parameters (`f(lo, hi)`), not crate free fns — never resolve.
        if len(name) == 1:
            return []
        return [
            (fi, gi)
            for fi, gi in cands
            if model.files[fi]["fns"][gi].ctx is None
        ]
    if qual == "self" and caller_ctx is not None:
        same = [
            (fi, gi)
            for fi, gi in cands
            if model.files[fi]["fns"][gi].ctx == caller_ctx
        ]
        if same:
            return same
    recv_ty = model.field_types.get(caller_ctx or "", {}).get(qual or "")
    if recv_ty in model.struct_names:
        on_ty = [
            (fi, gi)
            for fi, gi in cands
            if model.files[fi]["fns"][gi].ctx == recv_ty
        ]
        return on_ty
    if name in METHOD_EDGE_DENY:
        return []
    if name in model.trait_methods:
        return cands
    return cands if len(cands) == 1 else []


def reachable_from_hot_roots(model):
    """{(file_idx, fn_idx): sorted-list-of-root-labels} over non-test fns."""
    nodes, index = build_call_index(model)

    edges_of = {}
    for fi, gi in nodes:
        f = model.files[fi]
        fn = f["fns"][gi]
        resolved = []
        for name, kind, qual, ln, _ti in call_edges(f["toks"], fn):
            if lint_ok(f["scanned"], ln, "hot-path-alloc"):
                continue  # annotated call line: edge cut (dyn-dispatch false path)
            resolved.extend(resolve_call(model, index, name, kind, qual, fn.ctx))
        edges_of[(fi, gi)] = resolved

    roots = []
    for fi, gi in nodes:
        fn = model.files[fi]["fns"][gi]
        for rname, rctx in HOT_ROOTS:
            if fn.name == rname and (rctx is None or fn.ctx == rctx):
                roots.append((fi, gi))
                break

    reach = {}
    for root in roots:
        label = fn_label(model.files[root[0]]["fns"][root[1]])
        seen = {root}
        stack = [root]
        while stack:
            node = stack.pop()
            reach.setdefault(node, set()).add(label)
            for nxt in edges_of.get(node, []):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
    return {k: sorted(v) for k, v in reach.items()}


# --- hot-path-alloc (lints.rs) --------------------------------------------

ALLOC_TYPES = {"Vec", "VecDeque", "String", "Box", "HashMap", "HashSet",
               "BTreeMap", "BTreeSet", "Rc", "Arc"}
ALLOC_TYPE_METHODS = {"new", "with_capacity", "from"}
ALLOC_MACROS = {"vec", "format"}
ALLOC_METHODS = {"to_vec", "to_string", "to_owned", "clone", "collect"}
ARENA_SUFFIXES = ("Scratch", "Arena")


def lint_hot_path_alloc(model, sink):
    reach = reachable_from_hot_roots(model)
    for (fi, gi), roots in sorted(reach.items()):
        f = model.files[fi]
        fn = f["fns"][gi]
        if fn.ctx and any(fn.ctx.endswith(sfx) for sfx in ARENA_SUFFIXES):
            continue  # grow-only scratch arenas are the sanctioned allocator
        s = f["scanned"]
        fn_exempt = lint_ok(s, fn.sig_line, "hot-path-alloc")
        toks = f["toks"]
        start, end = fn.body
        roots_str = ", ".join(roots)
        i = start
        while i < end:
            t, ln = toks[i]
            marker = None
            if t in ALLOC_TYPES and i + 2 < end and toks[i + 1][0] == "::":
                k = i + 2
                if toks[k][0] == "<":
                    k = _skip_angle(toks, k)
                    if k < end and toks[k][0] == "::":
                        k += 1
                m = toks[k][0] if k < end else ""
                methods = {"new"} if t in ("Rc", "Arc") else ALLOC_TYPE_METHODS
                if m in methods:
                    k2 = k + 1
                    if k2 < end and toks[k2][0] == "::" and k2 + 1 < end and toks[k2 + 1][0] == "<":
                        k2 = _skip_angle(toks, k2 + 1)
                    if k2 < end and toks[k2][0] == "(":
                        marker = "%s::%s" % (t, m)
            elif t in ALLOC_MACROS and i + 1 < end and toks[i + 1][0] == "!":
                marker = t + "!"
            elif (
                t in ALLOC_METHODS
                and i > 0
                and toks[i - 1][0] == "."
            ):
                k = i + 1
                if k < end and toks[k][0] == "::" and k + 1 < end and toks[k + 1][0] == "<":
                    k = _skip_angle(toks, k + 1)
                if k < end and toks[k][0] == "(":
                    marker = ".%s()" % t
            if marker is not None:
                sink.emit(
                    s, f["rel"], ln, "hot-path-alloc",
                    "allocating construct `%s` in `%s`, reachable from %s — the "
                    "steady-state serving hot path must not allocate (grow-only "
                    "scratch arenas excepted; annotate intentional cold paths with "
                    "`// lint-ok(hot-path-alloc): <why>`)" % (marker, fn_label(fn), roots_str),
                    force_ok=fn_exempt,
                )
            i += 1


# --- unit-confusion (units.rs) --------------------------------------------

UNIT_SUFFIXES = (("_bytes", "bytes"), ("_tokens", "tokens"),
                 ("_pages", "pages"), ("_rows", "rows"))
UNITS = {"bytes", "tokens", "pages", "rows"}
# "ratio" marks `_per_`-named values (bytes_per_token, …): multiplying by a
# ratio converts the unit (result treated as unit-free), and a ratio never
# participates in a cross-unit conflict itself.
# Blessed converters: the value each returns carries its true unit even when
# the name's suffix says otherwise (`bytes_for_tokens` RETURNS bytes).
UNIT_CONVERTERS = {
    "bytes_for_tokens": "bytes",
    "token_bytes": "bytes",
    "cache_bytes_per_token": "ratio",
    "bytes_per_token": "ratio",
    "bytes_per_token_for": "ratio",
}
ADD_OPS = {"+", "-", "+=", "-="}
CMP_OPS = {"<", ">", "<=", ">=", "==", "!="}
UNARY_PREFIX = {"&", "mut", "*", "-", "+", "!"}
MUL_OPS = {"*", "/", "%"}


def suffix_unit(name):
    if "_per_" in name:
        return "ratio"
    for suf, unit in UNIT_SUFFIXES:
        if name.endswith(suf) or name == suf[1:]:
            return unit
    return None


def unit_for(name, env):
    if name in UNIT_CONVERTERS:
        return UNIT_CONVERTERS[name]
    if name in env:
        return env[name]
    return suffix_unit(name)


class UnitScanner:
    """Forward expression scanner over a fn body's tokens. Flags `+`/`-`
    and comparisons whose two terms carry different unit suffixes."""

    def __init__(self, toks, end, env, on_conflict):
        self.toks = toks
        self.end = end
        self.env = env
        self.on_conflict = on_conflict

    def tok(self, i):
        return self.toks[i][0] if i < self.end else ""

    def scan_region(self, i, end):
        saved = self.end
        self.end = min(end, saved)
        while i < self.end:
            if self.tok(i) == "let":
                i = self.parse_let(i)
                continue
            unit, j = self.parse_expr(i)
            i = j if j > i else i + 1
        self.end = saved

    def parse_let(self, i):
        # `let [mut] NAME [: ty] = expr` — bind NAME's unit in env.
        j = i + 1
        if self.tok(j) == "mut":
            j += 1
        if not tok_is_ident(self.tok(j)):
            return i + 1
        name = self.tok(j)
        j += 1
        # scan to `=` (stop at `;`); skip angle groups in type annotations
        while j < self.end and self.tok(j) not in ("=", ";"):
            if self.tok(j) == "<":
                j = _skip_angle(self.toks, j)
            else:
                j += 1
        if self.tok(j) != "=":
            self.env[name] = suffix_unit(name)
            return j + 1
        unit, k = self.parse_expr(j + 1)
        self.env[name] = suffix_unit(name) or unit
        return k if k > j + 1 else j + 2

    def parse_expr(self, i):
        lu, i = self.parse_term(i)
        while True:
            op = self.tok(i)
            if op in ADD_OPS or op in CMP_OPS:
                line = self.toks[i][1] if i < self.end else 0
                ru, j = self.parse_term(i + 1)
                if j == i + 1:
                    return lu, i
                if lu in UNITS and ru in UNITS and lu != ru:
                    self.on_conflict(line, lu, op, ru)
                lu = None if op in CMP_OPS else (lu or ru)
                i = j
            else:
                return lu, i

    def parse_term(self, i):
        u, i = self.parse_factor(i)
        while True:
            op = self.tok(i)
            if op in MUL_OPS:
                u2, j = self.parse_factor(i + 1)
                if j == i + 1:
                    return u, i
                if op == "*":
                    if u == "ratio" or u2 == "ratio":
                        u = None  # ratio factor converts the unit
                    elif u is not None and u2 is not None:
                        u = None  # mixed-unit product: dimensionally new
                    elif u2 is not None:
                        u = u2
                else:  # / %
                    if u2 is not None:
                        u = None  # unitful divisor: result is a ratio
                i = j
            else:
                return u, i

    def parse_factor(self, i):
        while self.tok(i) in UNARY_PREFIX:
            i += 1
        t = self.tok(i)
        if t == "(":
            close = _match_paren_toks(self.toks, i)
            inner, _ = self.parse_expr(i + 1)
            self.scan_rest_of_group(i + 1, close)
            return self.postfix(inner, close + 1, True)
        if tok_is_ident(t):
            return self.chain(i)
        if t and t[0].isdigit():
            return self.postfix(None, i + 1, False)
        return None, i

    def scan_rest_of_group(self, start, close):
        # After taking the group's leading expr for a unit, still walk the
        # remainder (later args, closure bodies) for nested conflicts.
        sub = UnitScanner(self.toks, close, self.env, self.on_conflict)
        sub.scan_region(start, close)

    def chain(self, i):
        last = self.tok(i)
        i += 1
        return self.postfix_chain(last, i)

    def postfix_chain(self, last, i):
        is_call = False
        while True:
            t = self.tok(i)
            if t == "::" and tok_is_ident(self.tok(i + 1)):
                last = self.tok(i + 1)
                i += 2
            elif t == "::" and self.tok(i + 1) == "<":
                i = _skip_angle(self.toks, i + 1)
            elif t == ".":
                nxt = self.tok(i + 1)
                if tok_is_ident(nxt):
                    last = nxt
                    i += 2
                elif nxt and nxt[0].isdigit():
                    i += 2
                else:
                    break
            elif t == "(":
                close = _match_paren_toks(self.toks, i)
                self.scan_rest_of_group(i + 1, close)
                is_call = True
                i = close + 1
            elif t == "[":
                close = _match_bracket_toks(self.toks, i)
                self.scan_rest_of_group(i + 1, close)
                i = close + 1
            elif t == "?":
                i += 1
            elif t == "as":
                # keep the operand's unit across `x as u64`
                i += 1
                while self.tok(i) in ("&", "mut"):
                    i += 1
                if tok_is_ident(self.tok(i)):
                    i += 1
                    while self.tok(i) == "::" and tok_is_ident(self.tok(i + 1)):
                        i += 2
                    if self.tok(i) == "<":
                        i = _skip_angle(self.toks, i)
            else:
                break
        return unit_for(last, self.env), i

    def postfix(self, unit, i, keep_unit):
        # Non-ident primaries only take `.0` / `?` / `as` postfix.
        while True:
            t = self.tok(i)
            if t == "." and self.tok(i + 1) and self.tok(i + 1)[0].isdigit():
                i += 2
            elif t == "?":
                i += 1
            elif t == "as":
                i += 1
                if tok_is_ident(self.tok(i)):
                    i += 1
            else:
                break
        return (unit if keep_unit else None), i


def lint_unit_confusion(model, sink):
    for f in model.files:
        s = f["scanned"]
        toks = f["toks"]
        for fn in f["fns"]:
            if fn.is_test:
                continue
            env = {}
            conflicts = []

            def on_conflict(line, lu, op, ru):
                conflicts.append((line, lu, op, ru))

            sc = UnitScanner(toks, fn.body[1], env, on_conflict)
            sc.scan_region(fn.body[0], fn.body[1])
            for line, lu, op, ru in conflicts:
                sink.emit(
                    s, f["rel"], line, "unit-confusion",
                    "cross-unit arithmetic: `%s` %s `%s` — convert explicitly "
                    "(bytes_for_tokens / token_bytes / cache_bytes_per_token) or "
                    "annotate `// lint-ok(unit-confusion): <why>`" % (lu, op, ru),
                )


# --- sendptr-escape (lints.rs) --------------------------------------------

SENDPTR_HOME = "rust/src/util/threadpool.rs"
DISJOINT_IDIOMS = {"parallel_for", "chunks", "chunks_mut", "chunks_exact",
                   "chunks_exact_mut", "split_at", "split_at_mut"}


def ident_set(text):
    return {t for t, _ in lex(scan(text).masked) if tok_is_ident(t)}


def lint_sendptr_escape(model, sink):
    miri_idents = ident_set(model.aux.get(AUX_MIRI, ""))
    for f in model.files:
        if f["rel"] == SENDPTR_HOME:
            continue
        toks = f["toks"]
        s = f["scanned"]
        for i, (t, ln) in enumerate(toks):
            if t != "SendPtr" or i + 1 >= len(toks) or toks[i + 1][0] != "(":
                continue
            fn = next(
                (g for g in f["fns"] if g.body[0] <= i < g.body[1]), None
            )
            if fn is None:
                sink.emit(
                    s, f["rel"], ln, "sendptr-escape",
                    "`SendPtr` constructed outside any function body — disjoint "
                    "write ranges cannot be derived statically here",
                )
                continue
            if fn.is_test:
                continue
            start, end = fn.body
            body_idents = {toks[k][0] for k in range(start, end)}
            if not (body_idents & DISJOINT_IDIOMS):
                sink.emit(
                    s, f["rel"], ln, "sendptr-escape",
                    "`SendPtr` constructed in `%s`, which derives no disjoint "
                    "ranges (no parallel_for / chunks / split_at idiom in the "
                    "body) — the Send/Sync contract requires provably disjoint "
                    "writes" % fn_label(fn),
                )
            if fn.name not in miri_idents:
                sink.emit(
                    s, f["rel"], ln, "sendptr-escape",
                    "`SendPtr` constructed in `%s`, but no test in %s names that "
                    "function — every SendPtr kernel must run under the Miri lane"
                    % (fn_label(fn), AUX_MIRI),
                )


# --- dispatch-parity-drift (lints.rs) -------------------------------------


def design_section(design, header_prefix):
    """Lines of the DESIGN.md section whose heading starts with the prefix,
    through the next heading of equal-or-higher level."""
    out = []
    collecting = False
    for line in design.split("\n"):
        if collecting and (line.startswith("### ") or line.startswith("## ")):
            break
        if line.startswith(header_prefix):
            collecting = True
        if collecting:
            out.append(line)
    return "\n".join(out)


def contains_ident(text, name):
    from_ = 0
    while True:
        p = text.find(name, from_)
        if p < 0:
            return False
        from_ = p + 1
        pre = text[p - 1] if p > 0 else " "
        post = text[p + len(name)] if p + len(name) < len(text) else " "
        if not is_ident_char(pre) and not is_ident_char(post):
            return True


def lint_dispatch_parity(model, sink):
    parity_idents = ident_set(model.aux.get(AUX_PARITY, ""))
    design_5e = design_section(model.aux.get(AUX_DESIGN, ""), "### §5e")
    for f in model.files:
        for st in f["structs"]:
            if st.name != "KernelDispatch" or st.is_test:
                continue
            s = f["scanned"]
            fns = f["fns"]
            toks = f["toks"]
            for fname, fline, first_ty in st.fields:
                if first_ty != "fn":
                    continue
                scalar_ok = any(
                    g.name == fname and "scalar" in g.mods for g in fns
                )
                simd_ok = any(g.name == fname and g.is_simd for g in fns)
                test_named = any(
                    t == fname and in_test(s, ln) for t, ln in toks
                )
                parity_ok = fname in parity_idents or test_named
                design_ok = contains_ident(design_5e, fname)
                base = "`KernelDispatch::%s`" % fname
                if not scalar_ok:
                    sink.emit(
                        s, f["rel"], fline, "dispatch-parity-drift",
                        base + " has no scalar arm (`fn %s` in `mod scalar`) — the "
                        "scalar tier is the bit-exact oracle every arm is judged "
                        "against" % fname,
                    )
                if not simd_ok:
                    sink.emit(
                        s, f["rel"], fline, "dispatch-parity-drift",
                        base + " has no feature-gated SIMD arm (`fn %s` under a "
                        '`#[cfg(.. feature = "simd" ..)]` item)' % fname,
                    )
                if not parity_ok:
                    sink.emit(
                        s, f["rel"], fline, "dispatch-parity-drift",
                        base + " is not named by any parity test (%s or a "
                        "`#[cfg(test)]` item in the defining file)" % AUX_PARITY,
                    )
                if not design_ok:
                    sink.emit(
                        s, f["rel"], fline, "dispatch-parity-drift",
                        base + " has no DESIGN.md §5e parity-table row naming it",
                    )


# --- concurrency stage (concurrency.rs) -----------------------------------
#
# Models lock / condvar / atomic / channel usage per function from the token
# stream plus the items pass's field-type table, propagates lock sets over
# the resolved call graph, and powers the four concurrency lints:
# lock-order, condvar-discipline, atomic-ordering, channel-lifecycle.
# Primitive calls (`.lock()`, `.wait()`, `.send()`, `spawn`, …) are on
# METHOD_EDGE_DENY, so the stage detects them by direct token/receiver-field
# analysis rather than via call-graph edges.

LOCK_TYPES = {"Mutex", "RwLock"}
ATOMIC_TYPES = {
    "AtomicBool", "AtomicUsize", "AtomicIsize", "AtomicU8", "AtomicU16",
    "AtomicU32", "AtomicU64", "AtomicI8", "AtomicI16", "AtomicI32",
    "AtomicI64",
}
ATOMIC_METHODS = {
    "load", "store", "swap", "fetch_add", "fetch_sub", "fetch_and",
    "fetch_or", "fetch_xor", "fetch_max", "fetch_min", "fetch_update",
    "compare_exchange", "compare_exchange_weak",
}
# Container methods that mutate the guarded value when called through a
# guard-rooted chain. Deliberately curated: read-only accessors must not
# make every lock acquisition look like a protocol-relevant write.
MUTATING_METHODS = {
    "push", "push_back", "push_front", "pop", "pop_back", "pop_front",
    "insert", "remove", "clear", "take", "replace", "drain", "extend",
    "truncate", "swap_remove",
}
# Assignment operators as the lexer emits them (compound ops that the
# lexer splits, like `&=`, cannot appear as single tokens).
ASSIGN_OPS = {"=", "+=", "-=", "*=", "/=", "%=", "<<=", ">>="}
WAIT_METHODS = ("wait", "wait_timeout")
RECV_METHODS = ("recv", "recv_timeout", "try_recv")
LOAD_ORDERINGS_OK = ("Acquire", "SeqCst")
STORE_ORDERINGS_OK = ("Release", "SeqCst")
RMW_ORDERINGS_OK = ("Acquire", "Release", "AcqRel", "SeqCst")


class ConcTables:
    """Field-name → owner tables for the sync primitives, built from every
    non-test struct's field table (items pass)."""

    def __init__(self, model):
        self.mutex_owners = {}  # field -> sorted owning struct names
        self.rwlock_fields = set()
        self.condvar_fields = set()
        self.condvar_structs = set()
        self.atomic_owners = {}  # field -> [(struct, ty, file_idx, line)]
        for fi, f in enumerate(model.files):
            for st in f["structs"]:
                if st.is_test:
                    continue
                for fname, fline, fty in st.fields:
                    if fty in LOCK_TYPES:
                        self.mutex_owners.setdefault(fname, []).append(st.name)
                        if fty == "RwLock":
                            self.rwlock_fields.add(fname)
                    elif fty == "Condvar":
                        self.condvar_fields.add(fname)
                        self.condvar_structs.add(st.name)
                    elif fty in ATOMIC_TYPES:
                        self.atomic_owners.setdefault(fname, []).append(
                            (st.name, fty, fi, fline)
                        )
        for v in self.mutex_owners.values():
            v.sort()

    def lock_identity(self, recv):
        """`Struct.field` when the receiver token is a lock field of exactly
        one struct, else the bare receiver token (local guards)."""
        owners = sorted(set(self.mutex_owners.get(recv, [])))
        if len(owners) == 1:
            return owners[0] + "." + recv
        return recv

    def atomic_field(self, recv):
        """(identity, ty, file_idx, decl_line) when the receiver is an
        atomic field of exactly one struct, else None."""
        owners = self.atomic_owners.get(recv, [])
        if len({o[0] for o in owners}) == 1:
            st, ty, fi, ln = owners[0]
            return (st + "." + recv, ty, fi, ln)
        return None


def _stmt_start(toks, i, lo):
    """Index of the first token of the statement containing token `i`."""
    j = i - 1
    while j >= lo:
        if toks[j][0] in (";", "{", "}"):
            return j + 1
        j -= 1
    return lo


def _close_delim(toks, i, end):
    """`i` at an opening bracket: index of its matching closer."""
    depth = 0
    j = i
    while j < end:
        t = toks[j][0]
        if t in ("(", "[", "{"):
            depth += 1
        elif t in (")", "]", "}"):
            depth -= 1
            if depth == 0:
                return j
        j += 1
    return end - 1


def _chain_walk(toks, j, end, saw_dot=False):
    """Walk a postfix chain (`.field`, `.method(..)`, `[..]`, `?`) starting
    at token `j`. Returns (end_idx, mutated): mutated when the chain calls a
    MUTATING_METHODS name or (after at least one `.`) lands on an assignment
    operator — i.e. it writes through whatever the chain is rooted in."""
    mutated = False
    while j < end:
        t = toks[j][0]
        if t == ".":
            saw_dot = True
            j += 1
            if j < end and toks[j][0] not in ("(", "["):
                name = toks[j][0]
                j += 1
                if j < end and toks[j][0] == "(":
                    if name in MUTATING_METHODS:
                        mutated = True
                    j = _close_delim(toks, j, end) + 1
            continue
        if t == "[":
            j = _close_delim(toks, j, end) + 1
            continue
        if t == "?":
            j += 1
            continue
        break
    if saw_dot and j < end and toks[j][0] in ASSIGN_OPS:
        mutated = True
    return j, mutated


def _guard_binding(toks, i, lo):
    """Guard variable a lock acquisition at token `i` is let-bound to, or
    None for a temporary guard (held only for its statement)."""
    b = _stmt_start(toks, i, lo)
    j = b
    while j < i:
        if toks[j][0] == "let":
            k = j + 1
            if k < i and toks[k][0] == "mut":
                k += 1
            if k < i and tok_is_ident(toks[k][0]) and toks[k][0] != "_":
                return toks[k][0]
            return None
        j += 1
    return None


def _guard_live_end(toks, i, end, guard):
    """Token index where the guard acquired at `i` dies: a same-depth
    `drop(guard)`, the enclosing block's close for let-bound guards, or the
    statement end for temporaries. Conditional (deeper-nested) drops do not
    cut the range — the guard is still held on the fall-through path."""
    depth = 0
    j = i
    while j < end:
        t = toks[j][0]
        if t in ("(", "[", "{"):
            depth += 1
        elif t in (")", "]", "}"):
            if depth == 0:
                return j
            depth -= 1
        elif depth == 0 and guard is None and t == ";":
            return j
        elif (
            depth == 0
            and guard is not None
            and t == "drop"
            and j + 2 < end
            and toks[j + 1][0] == "("
            and toks[j + 2][0] == guard
        ):
            return j
        j += 1
    return end


def _loop_ranges(toks, start, end):
    """Token ranges of every `loop`/`while`/`for` body in the fn."""
    out = []
    i = start
    while i < end:
        if toks[i][0] in ("loop", "while", "for"):
            depth = 0
            j = i + 1
            while j < end:
                t = toks[j][0]
                if t in ("(", "["):
                    depth += 1
                elif t in (")", "]"):
                    depth -= 1
                elif t == "{" and depth == 0:
                    out.append((j, _close_delim(toks, j, end)))
                    break
                j += 1
        i += 1
    return out


class FnConcurrency:
    """Per-function concurrency summary (one instance per non-test fn)."""

    __slots__ = ("acquisitions", "waits", "has_notify")

    def __init__(self):
        # [(identity, line, tok_idx, guard_or_None, live_end, mutated, mut_line)]
        self.acquisitions = []
        # [(method, line, guard_arg, in_loop, rebound)]
        self.waits = []
        self.has_notify = False


def summarize_fn(toks, fn, tables):
    start, end = fn.body
    summary = FnConcurrency()
    loops = _loop_ranges(toks, start, end)
    guards = {}  # guard var -> (identity, live_end, acq_idx-in-list)
    i = start
    while i < end:
        t, ln = toks[i]
        prev = toks[i - 1][0] if i > 0 else ""
        nxt = toks[i + 1][0] if i + 1 < end else ""
        if t in ("notify_one", "notify_all"):
            summary.has_notify = True
        elif prev == "." and nxt == "(" and i >= 2:
            recv = toks[i - 2][0]
            is_lock = t == "lock" or (
                t in ("read", "write") and recv in tables.rwlock_fields
            )
            if is_lock and tok_is_ident(recv):
                ident = tables.lock_identity(recv)
                guard = _guard_binding(toks, i, start)
                live_end = _guard_live_end(toks, i + 1, end, guard)
                # Temporary guards: a mutating postfix chain hanging off the
                # lock call itself (`x.lock().unwrap().field = v`).
                close = _close_delim(toks, i + 1, end)
                _, chain_mut = _chain_walk(toks, close + 1, end, saw_dot=True)
                mut_line = ln if chain_mut else 0
                summary.acquisitions.append(
                    [ident, ln, i, guard, live_end, chain_mut, mut_line]
                )
                if guard is not None:
                    guards[guard] = (ident, live_end, len(summary.acquisitions) - 1)
            elif t in WAIT_METHODS and recv in tables.condvar_fields:
                arg = toks[i + 2][0] if i + 2 < end else ""
                in_loop = any(lo < i < hi for lo, hi in loops)
                b = _stmt_start(toks, i, start)
                j = b
                if j < i and toks[j][0] == "let":
                    j += 1
                if j < i and toks[j][0] == "mut":
                    j += 1
                rebound = (
                    tok_is_ident(arg)
                    and j + 1 < i
                    and toks[j][0] == arg
                    and toks[j + 1][0] == "="
                )
                summary.waits.append((t, ln, arg, in_loop, rebound))
        elif tok_is_ident(t) and prev != "." and t in guards:
            # Guard-rooted use: `*g op=`, `g.path = v`, `g.container.push(..)`.
            ident, live_end, ai = guards[t]
            if i < live_end:
                acq = summary.acquisitions[ai]
                if not acq[5]:
                    if prev == "*" and nxt in ASSIGN_OPS:
                        acq[5], acq[6] = True, ln
                    else:
                        _, chain_mut = _chain_walk(toks, i + 1, end)
                        if chain_mut:
                            acq[5], acq[6] = True, ln
        i += 1
    return summary


def _spawn_sites(toks, fn):
    """Lines of `spawn(..)` calls whose JoinHandle is discarded (the spawn
    chain is a bare statement: not bound, not an argument, not returned)."""
    out = []
    start, end = fn.body
    i = start
    while i < end:
        if toks[i][0] == "spawn" and i + 1 < end and toks[i + 1][0] == "(":
            close = _close_delim(toks, i + 1, end)
            j, _ = _chain_walk(toks, close + 1, end)
            if j < end and toks[j][0] == ";":
                b = _stmt_start(toks, i, start)
                depth = 0
                used = False
                for k in range(b, i):
                    t = toks[k][0]
                    if t in ("(", "["):
                        depth += 1
                    elif t in (")", "]"):
                        depth -= 1
                    elif t in ("let", "=", "return", "=>"):
                        used = True
                        break
                if depth > 0:
                    used = True
                if not used:
                    out.append(toks[i][1])
        i += 1
    return out


def _recv_unwrap_sites(toks, fn):
    """Lines where a channel receive is `.unwrap()`/`.expect()`-ed."""
    out = []
    start, end = fn.body
    i = start
    while i < end:
        if (
            toks[i][0] in RECV_METHODS
            and i > 0
            and toks[i - 1][0] == "."
            and i + 1 < end
            and toks[i + 1][0] == "("
        ):
            close = _close_delim(toks, i + 1, end)
            if (
                close + 2 < end
                and toks[close + 1][0] == "."
                and toks[close + 2][0] in ("unwrap", "expect")
            ):
                out.append(toks[i][1])
        i += 1
    return out


def lint_concurrency(model, sink):
    """The four whole-program concurrency rules over every non-test fn."""
    tables = ConcTables(model)
    nodes, index = build_call_index(model)
    summaries = {}
    for fi, gi in nodes:
        f = model.files[fi]
        summaries[(fi, gi)] = summarize_fn(f["toks"], f["fns"][gi], tables)

    # Resolved call edges with token positions (for held-guard call ranges).
    calls_of = {}
    edges_of = {}
    for fi, gi in nodes:
        f = model.files[fi]
        fn = f["fns"][gi]
        calls = []
        targets = []
        for name, kind, qual, ln, ti in call_edges(f["toks"], fn):
            resolved = resolve_call(model, index, name, kind, qual, fn.ctx)
            if resolved:
                calls.append((ti, ln, resolved))
                targets.extend(resolved)
        calls_of[(fi, gi)] = calls
        edges_of[(fi, gi)] = targets

    # Transitive lock sets: direct acquisitions closed over call edges.
    trans = {n: {a[0] for a in summaries[n].acquisitions} for n in nodes}
    changed = True
    while changed:
        changed = False
        for n in nodes:
            for callee in edges_of[n]:
                extra = trans[callee] - trans[n]
                if extra:
                    trans[n] |= extra
                    changed = True

    # --- lock-order: acquisition-order graph + cycle detection ------------
    edge_sites = {}  # (held, acquired) -> (file_idx, line)
    for fi, gi in nodes:
        summary = summaries[(fi, gi)]
        for ident, _ln, ti, _guard, live_end, _mut, _ml in summary.acquisitions:
            for o_ident, o_ln, o_ti, _g2, _le2, _m2, _ml2 in summary.acquisitions:
                if o_ti > ti and o_ti < live_end:
                    edge_sites.setdefault((ident, o_ident), (fi, o_ln))
            for c_ti, c_ln, resolved in calls_of[(fi, gi)]:
                if c_ti > ti and c_ti < live_end:
                    for callee in resolved:
                        for callee_lock in sorted(trans[callee]):
                            edge_sites.setdefault((ident, callee_lock), (fi, c_ln))
    adj = {}
    for held, acquired in edge_sites:
        adj.setdefault(held, set()).add(acquired)

    def reaches(src, dst):
        seen = {src}
        stack = [src]
        while stack:
            u = stack.pop()
            if u == dst:
                return True
            for v in adj.get(u, ()):
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
        return False

    ordered_edges = sorted(
        edge_sites.items(),
        key=lambda kv: (model.files[kv[1][0]]["rel"], kv[1][1], kv[0]),
    )
    for (held, acquired), (fi, ln) in ordered_edges:
        if reaches(acquired, held):
            f = model.files[fi]
            sink.emit(
                f["scanned"], f["rel"], ln, "lock-order",
                "acquiring `%s` while holding `%s` closes an acquisition-order "
                "cycle (`%s` is also held when `%s` is taken elsewhere) — "
                "potential deadlock" % (acquired, held, acquired, held),
            )

    # --- condvar-discipline + atomic-ordering + channel-lifecycle ---------
    atomic_usage = {}  # identity -> {"load"/"store": {ordering}} + decl site
    for fi, gi in nodes:
        f = model.files[fi]
        fn = f["fns"][gi]
        s = f["scanned"]
        summary = summaries[(fi, gi)]

        for meth, ln, _arg, in_loop, rebound in summary.waits:
            if not (in_loop and rebound):
                sink.emit(
                    s, f["rel"], ln, "condvar-discipline",
                    "`Condvar::%s` outside a predicate loop: the guard must be "
                    "rebound from the wait result inside a `loop`/`while` that "
                    "re-checks the predicate under the lock" % meth,
                )
        reported = set()
        for ident, _ln, _ti, _guard, _le, mutated, mut_line in summary.acquisitions:
            struct = ident.split(".")[0] if "." in ident else None
            if (
                mutated
                and struct in tables.condvar_structs
                and not summary.has_notify
                and ident not in reported
            ):
                reported.add(ident)
                sink.emit(
                    s, f["rel"], mut_line, "condvar-discipline",
                    "state guarded by `%s` is mutated but `%s` never calls "
                    "`notify_one`/`notify_all` on the paired condvar — a "
                    "waiter can miss this update" % (ident, fn_label(fn)),
                )

        start, end = fn.body
        i = start
        while i < end:
            t = f["toks"][i][0]
            if (
                t in ATOMIC_METHODS
                and i > 0
                and f["toks"][i - 1][0] == "."
                and i + 1 < end
                and f["toks"][i + 1][0] == "("
            ):
                close = _close_delim(f["toks"], i + 1, end)
                orderings = []
                for j in range(i + 2, close - 1):
                    if (
                        f["toks"][j][0] == "Ordering"
                        and f["toks"][j + 1][0] == "::"
                    ):
                        orderings.append((f["toks"][j + 2][0], f["toks"][j + 2][1]))
                if orderings:
                    recv = f["toks"][i - 2][0] if i >= 2 else ""
                    info = tables.atomic_field(recv) if tok_is_ident(recv) else None
                    for ordv, oln in orderings:
                        if info is not None and info[1] == "AtomicBool":
                            ok = (
                                (t == "load" and ordv in LOAD_ORDERINGS_OK)
                                or (t == "store" and ordv in STORE_ORDERINGS_OK)
                                or (
                                    t not in ("load", "store")
                                    and ordv in RMW_ORDERINGS_OK
                                )
                            )
                            if not ok:
                                sink.emit(
                                    s, f["rel"], oln, "atomic-ordering",
                                    "flag `%s` %s uses `Ordering::%s` — "
                                    "load/store flag pairs must use "
                                    "Acquire/Release or SeqCst" % (info[0], t, ordv),
                                )
                        elif ordv == "Relaxed":
                            sink.emit(
                                s, f["rel"], oln, "atomic-ordering",
                                "`Ordering::Relaxed` on `%s` — Relaxed is only "
                                "legal on sites annotated as monotonic "
                                "counters/gauges (lint-ok with the monotonicity "
                                "argument), otherwise upgrade the ordering"
                                % (info[0] if info else recv),
                            )
                    if info is not None and t in ("load", "store"):
                        slot = atomic_usage.setdefault(
                            info[0], {"decl": (info[2], info[3]), "load": set(), "store": set()}
                        )
                        for ordv, _oln in orderings:
                            slot[t].add(ordv)
            i += 1

        for ln in _spawn_sites(f["toks"], fn):
            sink.emit(
                s, f["rel"], ln, "channel-lifecycle",
                "spawned thread's JoinHandle is discarded — a `Sender` moved "
                "into a detached thread can outlive teardown and hang its "
                "receiver; bind and join the handle (or lint-ok with the "
                "teardown story)",
            )
        for ln in _recv_unwrap_sites(f["toks"], fn):
            sink.emit(
                s, f["rel"], ln, "channel-lifecycle",
                "channel receive result is unwrapped — a dropped sender "
                "becomes a teardown panic; match the `Err` and exit the "
                "receive loop instead",
            )

    # Per-field ordering consistency (flag pairs must not mix disciplines).
    for ident in sorted(atomic_usage):
        slot = atomic_usage[ident]
        fi, ln = slot["decl"]
        f = model.files[fi]
        for cls in ("load", "store"):
            if len(slot[cls]) > 1:
                sink.emit(
                    f["scanned"], f["rel"], ln, "atomic-ordering",
                    "atomic field `%s` mixes %s orderings {%s} — pick one "
                    "discipline per field"
                    % (ident, cls, ", ".join(sorted(slot[cls]))),
                )


# --- crate driver ---------------------------------------------------------


def lint_crate(file_pairs, aux):
    """All thirteen lints over a set of (rel, src) files + aux artifacts.
    Returns (findings sorted by (file, line, rule), suppressed_count)."""
    model = CrateModel.build(file_pairs, aux)
    sink = Sink()
    for f in model.files:
        rel, s = f["rel"], f["scanned"]
        lint_accounting_fields(rel, s, sink)
        lint_lossy_casts(rel, s, sink)
        lint_safety_comments(rel, s, sink)
        lint_hot_path_panics(rel, s, sink)
        lint_simd_gating(rel, s, sink)
    lint_hot_path_alloc(model, sink)
    lint_unit_confusion(model, sink)
    lint_sendptr_escape(model, sink)
    lint_dispatch_parity(model, sink)
    lint_concurrency(model, sink)
    sink.findings.sort(key=lambda x: (x["file"], x["line"], x["rule"], x["msg"]))
    return sink.findings, sink.suppressed


def rust_files(root):
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for name in sorted(filenames):
            if name.endswith(".rs"):
                out.append(os.path.join(dirpath, name))
    return out


def read_aux_from_repo():
    aux = {}
    for rel in AUX_PATHS:
        path = os.path.join(REPO, rel)
        if os.path.exists(path):
            with open(path, encoding="utf-8") as fh:
                aux[rel] = fh.read()
    return aux


def cmd_lint(fmt, rule=None):
    files = []
    for path in rust_files(os.path.join(REPO, "rust", "src")):
        rel = os.path.relpath(path, REPO).replace("\\", "/")
        with open(path, encoding="utf-8") as fh:
            files.append((rel, fh.read()))
    if not files:
        print("lint_mirror: no Rust sources found", file=sys.stderr)
        return 1
    findings, suppressed = lint_crate(files, read_aux_from_repo())
    if rule is not None:
        findings = [f for f in findings if f["rule"] == rule]
    if fmt == "json":
        print(json.dumps(
            {"findings": findings, "suppressed": suppressed, "files": len(files)},
            indent=2, sort_keys=True,
        ))
    elif fmt == "sarif":
        print(json.dumps(sarif_report(findings), indent=2, sort_keys=True))
    else:
        for f in findings:
            print("%s:%d: [%s] %s" % (f["file"], f["line"], f["rule"], f["msg"]))
        if findings:
            print("lint_mirror: %d finding(s), %d suppressed by lint-ok"
                  % (len(findings), suppressed), file=sys.stderr)
        else:
            print("lint_mirror: %d files clean (%d finding(s) suppressed by lint-ok)"
                  % (len(files), suppressed))
    return 1 if findings else 0


def sarif_report(findings):
    return {
        "$schema": "https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/Schemata/sarif-schema-2.1.0.json",
        "version": "2.1.0",
        "runs": [
            {
                "tool": {
                    "driver": {
                        "name": "kqsvd-xtask-lint",
                        "informationUri": "https://example.invalid/kqsvd/DESIGN.md",
                        "rules": [{"id": r} for r in RULES],
                    }
                },
                "results": [
                    {
                        "ruleId": f["rule"],
                        "level": "error",
                        "message": {"text": f["msg"]},
                        "locations": [
                            {
                                "physicalLocation": {
                                    "artifactLocation": {"uri": f["file"]},
                                    "region": {"startLine": f["line"]},
                                }
                            }
                        ],
                    }
                    for f in findings
                ],
            }
        ],
    }


# --- fixtures -------------------------------------------------------------

SECTION_PREFIX = "//=== file: "


def split_fixture(text):
    """(main_text, extra_files, aux) — sections split on `//=== file:` lines."""
    lines = text.split("\n")
    sections = []  # (path-or-None, [lines])
    cur_path = None
    cur = []
    for line in lines:
        if line.startswith(SECTION_PREFIX):
            sections.append((cur_path, cur))
            cur_path = line[len(SECTION_PREFIX) :].strip()
            cur = []
        else:
            cur.append(line)
    sections.append((cur_path, cur))
    main = "\n".join(sections[0][1])
    extra = []
    aux = {}
    for path, body_lines in sections[1:]:
        body = "\n".join(body_lines)
        if path in AUX_PATHS:
            aux[path] = body
        else:
            extra.append((path, body))
    return main, extra, aux


def fixture_headers(main):
    lint_as = None
    expect = None
    for line in main.split("\n")[:10]:
        if line.startswith("// lint-as:"):
            lint_as = line[len("// lint-as:") :].strip()
        if line.startswith("// expect-lint:"):
            expect = line[len("// expect-lint:") :].strip()
    return lint_as, expect


def run_fixture(text):
    """Returns (findings, expect) or raises ValueError."""
    main, extra, aux = split_fixture(text)
    lint_as, expect = fixture_headers(main)
    if lint_as is None or expect is None:
        raise ValueError("missing `// lint-as:` / `// expect-lint:` headers")
    if expect != "none" and expect not in RULES:
        raise ValueError("unknown rule `%s` in expect-lint header" % expect)
    files = [(lint_as, main)] + extra
    findings, _ = lint_crate(files, aux)
    return findings, expect


def registration_selfcheck():
    """Every rule id must appear in the fixture corpus, CI, and DESIGN §9."""
    errors = []
    fdir = os.path.join(REPO, "xtask", "fixtures")
    expects = []
    for path in rust_files(fdir):
        with open(path, encoding="utf-8") as fh:
            main, _, _ = split_fixture(fh.read())
        _, expect = fixture_headers(main)
        if expect:
            expects.append(expect)
    ci = ""
    ci_path = os.path.join(REPO, ".github", "workflows", "ci.yml")
    if os.path.exists(ci_path):
        with open(ci_path, encoding="utf-8") as fh:
            ci = fh.read()
    design = ""
    d_path = os.path.join(REPO, "DESIGN.md")
    if os.path.exists(d_path):
        with open(d_path, encoding="utf-8") as fh:
            design = fh.read()
    design_9 = design_section(design, "## §9")
    for rule in RULES:
        if rule not in expects:
            errors.append("rule `%s` has no fixture (expect-lint header)" % rule)
        if rule not in ci:
            errors.append("rule `%s` not named in .github/workflows/ci.yml" % rule)
        if rule not in design_9:
            errors.append("rule `%s` not documented in DESIGN.md §9" % rule)
    if "none" not in expects:
        errors.append("no clean control fixture (expect-lint: none)")
    return errors


def cmd_fixtures(emit):
    fdir = os.path.join(REPO, "xtask", "fixtures")
    paths = rust_files(fdir)
    if not paths:
        print("lint_mirror fixtures: none found under %s" % fdir, file=sys.stderr)
        return 1
    failed = 0
    for path in paths:
        name = os.path.basename(path)
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        try:
            findings, expect = run_fixture(text)
        except ValueError as e:
            print("fixture %s: FAILED — %s" % (name, e), file=sys.stderr)
            failed += 1
            continue
        if emit:
            for f in findings:
                print("%s|%s|%d|%s" % (name, f["file"], f["line"], f["rule"]))
            continue
        if expect == "none":
            if findings:
                f0 = findings[0]
                print(
                    "fixture %s: FAILED — clean control tripped %d finding(s): "
                    "first = %s:%d [%s]" % (name, len(findings), f0["file"], f0["line"], f0["rule"]),
                    file=sys.stderr,
                )
                failed += 1
            else:
                print("fixture %s: ok" % name)
        elif any(f["rule"] == expect for f in findings):
            print("fixture %s: ok" % name)
        else:
            print(
                "fixture %s: FAILED — expected a `%s` finding but got %s"
                % (name, expect, sorted({f["rule"] for f in findings})),
                file=sys.stderr,
            )
            failed += 1
    if emit:
        return 0
    for err in registration_selfcheck():
        print("registration self-check: FAILED — %s" % err, file=sys.stderr)
        failed += 1
    if failed == 0:
        print("lint_mirror fixtures: %d fixture(s) verified; registration "
              "self-check passed (%d rules)" % (len(paths), len(RULES)))
        return 0
    print("lint_mirror fixtures: %d failure(s)" % failed, file=sys.stderr)
    return 1


def main(argv):
    args = list(argv[1:])
    cmd = args.pop(0) if args and not args[0].startswith("-") else "lint"
    fmt = "human"
    emit = False
    rule = None
    while args:
        a = args.pop(0)
        if a == "--format" and args:
            fmt = args.pop(0)
        elif a.startswith("--format="):
            fmt = a.split("=", 1)[1]
        elif a == "--rule" and args:
            rule = args.pop(0)
        elif a.startswith("--rule="):
            rule = a.split("=", 1)[1]
        elif a == "--emit-findings":
            emit = True
        else:
            print("usage: lint_mirror.py <lint|fixtures> [--format human|json|sarif] "
                  "[--rule <id>] [--emit-findings]", file=sys.stderr)
            return 2
    if rule is not None and rule not in RULES:
        print("lint_mirror: unknown rule `%s` (known: %s)" % (rule, ", ".join(RULES)),
              file=sys.stderr)
        return 2
    if cmd == "lint":
        return cmd_lint(fmt, rule)
    if cmd == "fixtures":
        return cmd_fixtures(emit)
    print("unknown command `%s`" % cmd, file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main(sys.argv))
