//! FIG1 — regenerates Figure 1: per-layer relative output error (top) and
//! mean component errors on K, Q, V, KQᵀ, MHA output (bottom), for the three
//! methods across the four zoo models (2 MHA + 2 GQA).
//!
//! Paper-expected shape: K-SVD best on K but worst on Q/scores/output (worse
//! still on GQA models); Eigen ≈ KQ-SVD on components; KQ-SVD strictly best
//! on KQᵀ and output. Set KQSVD_BENCH_FULL=1 for the larger protocol.
//!
//! Run: `cargo bench --bench fig1_methods`

use kqsvd::bench_support::{f as fnum, Table};
use kqsvd::config::{CalibConfig, Method, ZOO};
use kqsvd::eval::{figure1_for_model, model_for};
use kqsvd::text::Corpus;
use kqsvd::util::stats::Timer;

fn main() {
    let full = std::env::var("KQSVD_BENCH_FULL").is_ok();
    let calib = if full {
        CalibConfig::default() // 32×512 / 8×512
    } else {
        CalibConfig {
            n_calib_seqs: 8,
            calib_seq_len: 256,
            n_eval_seqs: 2,
            eval_seq_len: 256,
            ..CalibConfig::default()
        }
    };
    println!(
        "FIG1: {} calib × {}, {} eval × {}, ε = {}\n",
        calib.n_calib_seqs, calib.calib_seq_len, calib.n_eval_seqs, calib.eval_seq_len, calib.epsilon
    );

    let mut bottom = Table::new(&["model", "method", "K", "Q", "V", "KQt", "output"]);
    let mut top = Table::new(&["model", "method", "layer", "output_err"]);
    let mut ok = true;
    for name in ZOO {
        let t = Timer::start();
        let model = model_for(name);
        let corpus = Corpus::new(model.cfg.vocab_size, calib.seed);
        let (results, _) = figure1_for_model(&model, &corpus, &calib);
        println!("  {name}: evaluated 3 methods in {:.1}s", t.elapsed_secs());
        let get = |m: Method| results.iter().find(|r| r.method == m).unwrap();
        // The paper's orderings, asserted per model:
        let (ks, ei, kq) = (get(Method::KSvd), get(Method::Eigen), get(Method::KqSvd));
        ok &= kq.components.scores <= ks.components.scores + 1e-9;
        ok &= kq.components.scores <= ei.components.scores + 1e-9;
        ok &= ks.components.k <= kq.components.k + 1e-9;
        ok &= ks.components.q >= ei.components.q - 1e-9;
        ok &= kq.components.output <= ks.components.output + 1e-9;
        ok &= kq.components.output <= ei.components.output + 1e-9;
        for r in &results {
            bottom.row(&[
                name.to_string(),
                r.method.name().to_string(),
                fnum(r.components.k, 4),
                fnum(r.components.q, 4),
                fnum(r.components.v, 4),
                fnum(r.components.scores, 4),
                fnum(r.components.output, 4),
            ]);
            for (li, e) in r.per_layer_output.iter().enumerate() {
                top.row(&[name.to_string(), r.method.name().to_string(), li.to_string(), fnum(*e, 5)]);
            }
        }
    }
    println!("\nFigure 1 (bottom) — mean relative errors:");
    bottom.print();
    bottom.write_csv("fig1_components.csv").unwrap();
    top.write_csv("fig1_per_layer.csv").unwrap();
    println!(
        "\npaper-shape check (KQ-SVD best on KQᵀ+output, K-SVD best on K, worst on Q): {}",
        if ok { "HOLDS" } else { "VIOLATED" }
    );
    println!("CSV → bench_out/fig1_components.csv, bench_out/fig1_per_layer.csv");
    assert!(ok, "Figure-1 ordering violated");
}
