//! FIG2 — regenerates Figure 2: mean relative output error vs unbalance
//! factor β (K·β, Q/β) on the Llama2-7B analog.
//!
//! Paper-expected shape: K-SVD and KQ-SVD flat in β; Eigen rises toward
//! K-SVD, nearly indistinguishable by β = 10 (Theorem 4).
//!
//! Run: `cargo bench --bench fig2_unbalance`

use kqsvd::bench_support::{f as fnum, Table};
use kqsvd::config::{CalibConfig, Method};
use kqsvd::eval::figure2_for_model;
use kqsvd::model::Transformer;
use kqsvd::text::Corpus;

fn main() {
    let full = std::env::var("KQSVD_BENCH_FULL").is_ok();
    let calib = CalibConfig {
        n_calib_seqs: if full { 32 } else { 8 },
        calib_seq_len: if full { 512 } else { 256 },
        n_eval_seqs: 2,
        eval_seq_len: 256,
        ..CalibConfig::default()
    };
    let betas = [1.0f32, 2.0, 5.0, 10.0];
    let mcfg = kqsvd::config::preset("mha-small").unwrap();
    println!("FIG2 on {} — β ∈ {betas:?}\n", mcfg.name);
    let model = Transformer::init(mcfg.clone());
    let corpus = Corpus::new(mcfg.vocab_size, calib.seed);
    let sweep = figure2_for_model(&model, &corpus, &calib, &betas);

    let mut t = Table::new(&["beta", "ksvd", "eigen", "kqsvd", "eigen-ksvd gap"]);
    let get = |row: &Vec<(Method, f64)>, m: Method| row.iter().find(|(mm, _)| *mm == m).unwrap().1;
    let mut gaps = Vec::new();
    for (beta, row) in &sweep {
        let (ks, ei, kq) = (get(row, Method::KSvd), get(row, Method::Eigen), get(row, Method::KqSvd));
        gaps.push((ei - ks).abs());
        t.row(&[format!("{beta}"), fnum(ks, 5), fnum(ei, 5), fnum(kq, 5), fnum((ei - ks).abs(), 5)]);
    }
    t.print();
    t.write_csv("fig2_unbalance.csv").unwrap();

    // Shape assertions (Theorem 4 + invariances).
    let ks0 = get(&sweep[0].1, Method::KSvd);
    let ksl = get(&sweep.last().unwrap().1, Method::KSvd);
    let kq0 = get(&sweep[0].1, Method::KqSvd);
    let kql = get(&sweep.last().unwrap().1, Method::KqSvd);
    assert!((ks0 - ksl).abs() < 0.05 * ks0, "K-SVD must be flat in β");
    assert!((kq0 - kql).abs() < 0.05 * kq0, "KQ-SVD must be flat in β");
    assert!(
        gaps.last().unwrap() < &(0.35 * gaps[0]),
        "Eigen must converge to K-SVD: gaps {gaps:?}"
    );
    println!("\npaper-shape check (flat ksvd/kqsvd, Eigen→K-SVD by β=10): HOLDS");
    println!("CSV → bench_out/fig2_unbalance.csv");
}
