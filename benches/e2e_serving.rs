//! E2E — end-to-end serving benchmark: throughput, latency and cache bytes,
//! exact vs KQ-SVD-compressed cache, through the full router/batcher stack.
//! Covers both serving modes — offline drain (`Router::run_offline`) and the
//! streaming session API (`Router::serve` + `EngineHandle`) — which share
//! one scheduling path, so the delta between the rows is pure session
//! overhead (channels + engine thread).
//!
//! Run: `cargo bench --bench e2e_serving`  (PJRT row needs `make artifacts`)

use kqsvd::bench_support::{f as fnum, Table};
use kqsvd::config::{Config, Method};
use kqsvd::coordinator::{BatcherConfig, Request, RequestHandle, Router};
use kqsvd::server::build_engine;
use kqsvd::text::{Corpus, Split};
use kqsvd::util::stats::fmt_bytes;

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Offline,
    Session,
}

impl Mode {
    fn name(self) -> &'static str {
        match self {
            Mode::Offline => "offline",
            Mode::Session => "session",
        }
    }
}

struct RunResult {
    tok_per_s: f64,
    ttft_p50: f64,
    ttft_p95: f64,
    tpot_mean: f64,
    cache_per_tok: usize,
    peak_bytes: u64,
}

fn run(
    method: Method,
    backend: &str,
    max_batch: usize,
    n_requests: usize,
    mode: Mode,
) -> anyhow::Result<RunResult> {
    let mut cfg = Config::from_preset("mha-small").map_err(anyhow::Error::msg)?;
    cfg.method = method;
    cfg.serve.backend = backend.into();
    cfg.serve.max_batch = max_batch;
    cfg.calib.n_calib_seqs = 8;
    cfg.calib.calib_seq_len = 256;
    cfg.run_dir = format!("runs/bench_e2e_{}_{}", method.name(), backend);
    let mut engine = build_engine(&cfg)?;
    let cache_per_tok = engine.cache_bytes_per_token();
    let mut router = Router::new(BatcherConfig::from(&cfg.serve));
    let corpus = Corpus::new(cfg.model.vocab_size, 99);
    let prompts: Vec<Vec<u32>> = (0..n_requests)
        .map(|i| corpus.sequence(Split::Validation, 2_000 + i as u64, 96))
        .collect();

    let metrics = match mode {
        Mode::Offline => {
            for (i, prompt) in prompts.into_iter().enumerate() {
                router
                    .submit(&engine, Request::new(i as u64, prompt, 32))
                    .map_err(|e| anyhow::anyhow!("{e:?}"))?;
            }
            let done = router.run_offline(&mut engine)?;
            assert_eq!(done.len(), n_requests);
            router.metrics.clone()
        }
        Mode::Session => {
            let handle = router.serve(Box::new(engine));
            let submissions: Vec<RequestHandle> = prompts
                .into_iter()
                .enumerate()
                .map(|(i, prompt)| handle.submit(Request::new(i as u64, prompt, 32)))
                .collect();
            for rh in submissions {
                rh.wait()?;
            }
            let m = handle.metrics();
            handle.join()?;
            m
        }
    };

    let (_, _, ttft_p50, ttft_p95, ..) = metrics.summary_stats("ttft_ms").unwrap();
    let (_, tpot_mean, ..) = metrics.summary_stats("tpot_ms").unwrap();
    Ok(RunResult {
        tok_per_s: metrics.gauge_value("decode_tok_per_s").unwrap_or(0.0),
        ttft_p50,
        ttft_p95,
        tpot_mean,
        cache_per_tok,
        peak_bytes: metrics.gauge_value("cache_peak_bytes").unwrap_or(0.0) as u64,
    })
}

fn main() -> anyhow::Result<()> {
    let n_requests = 16;
    println!("E2E serving bench: {n_requests} requests × (96 prompt + 32 gen), mha-small\n");
    let mut t = Table::new(&[
        "method", "backend", "mode", "batch", "tok/s", "ttft p50(ms)", "ttft p95(ms)",
        "tpot(ms)", "cache/tok", "peak cache",
    ]);
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let mut comp_vs_exact = (0.0f64, 0.0f64);
    for (method, backend) in [
        (Method::None, "rust"),
        (Method::KqSvd, "rust"),
        (Method::None, "pjrt"),
        (Method::KqSvd, "pjrt"),
    ] {
        if backend == "pjrt" && !have_artifacts {
            println!("  (skipping pjrt rows — run `make artifacts`)");
            continue;
        }
        // The session rows only run on the rust backend: they measure
        // streaming overhead, which is backend-independent.
        let modes: &[Mode] = if backend == "rust" {
            &[Mode::Offline, Mode::Session]
        } else {
            &[Mode::Offline]
        };
        for batch in [1usize, 8] {
            for &mode in modes {
                let r = run(method, backend, batch, n_requests, mode)?;
                if backend == "rust" && batch == 8 && mode == Mode::Offline {
                    if method == Method::None {
                        comp_vs_exact.0 = r.tok_per_s;
                    } else {
                        comp_vs_exact.1 = r.tok_per_s;
                    }
                }
                t.row(&[
                    method.name().into(),
                    backend.into(),
                    mode.name().into(),
                    batch.to_string(),
                    fnum(r.tok_per_s, 1),
                    fnum(r.ttft_p50, 2),
                    fnum(r.ttft_p95, 2),
                    fnum(r.tpot_mean, 3),
                    fmt_bytes(r.cache_per_tok as u64),
                    fmt_bytes(r.peak_bytes),
                ]);
            }
        }
    }
    t.print();
    t.write_csv("e2e_serving.csv")?;
    let (exact, comp) = comp_vs_exact;
    println!(
        "\ncompressed/exact decode throughput at batch 8 (rust, offline): {:.2}×",
        comp / exact.max(1e-9)
    );
    println!("CSV → bench_out/e2e_serving.csv");
    Ok(())
}
