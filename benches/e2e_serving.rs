//! E2E — end-to-end serving benchmark: throughput, latency and cache bytes,
//! exact vs KQ-SVD-compressed cache, through the full router/batcher stack.
//! Covers both serving modes — offline drain (`Router::run_offline`) and the
//! streaming session API (`Router::serve` + `EngineHandle`) — which share
//! one scheduling path, so the delta between the rows is pure session
//! overhead (channels + engine thread) — plus a **serial-vs-batch** section
//! comparing the batch-major GEMM execution path against the serial
//! `forward_token` oracle on the `test-tiny` preset, and three acceptance
//! scenarios: **long-prompt interleave** (decode streams must not stall
//! while a long prompt prefills), **preemption under pressure** (a
//! priority-1 request is admitted under a full budget by evicting a
//! priority-0 stream, which later resumes and completes), and
//! **shared prefix** (N requests with a common 256-token system prompt hit
//! the shared-page prefix cache; pool bytes grow sublinearly in the number
//! of concurrent same-prefix sequences).
//!
//! Results are printed as a table, written to `bench_out/e2e_serving.csv`,
//! and summarized into `BENCH_serving.json` at the repository root so the
//! perf trajectory is machine-readable across PRs.
//!
//! Run: `cargo bench --bench e2e_serving`  (PJRT row needs `make artifacts`)
//! CI smoke mode: `KQSVD_BENCH_SMOKE=1 cargo bench --bench e2e_serving`
//! shrinks calibration and the request count so the job finishes quickly.

use kqsvd::bench_support::{f as fnum, Table};
use kqsvd::config::{Config, Method};
use kqsvd::coordinator::metrics::names as metric_names;
use kqsvd::coordinator::{Batcher, BatcherConfig, GenParams, Request, RequestHandle, Router, StepOutcome};
use kqsvd::jsonutil::Json;
use kqsvd::kvcache::KvDtype;
use kqsvd::server::{build_engine, ServingEngine};
use kqsvd::text::{Corpus, Split};
use kqsvd::util::stats::fmt_bytes;

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Offline,
    Session,
}

impl Mode {
    fn name(self) -> &'static str {
        match self {
            Mode::Offline => "offline",
            Mode::Session => "session",
        }
    }
}

struct RunResult {
    decode_tok_per_s: f64,
    prefill_tok_per_s: f64,
    ttft_p50: f64,
    ttft_p95: f64,
    tpot_mean: f64,
    cache_per_tok: u64,
    peak_bytes: u64,
}

struct Workload {
    preset: &'static str,
    n_requests: usize,
    prompt_len: usize,
    gen_len: usize,
    calib_seqs: usize,
    calib_len: usize,
}

#[allow(clippy::too_many_arguments)]
fn run(
    w: &Workload,
    method: Method,
    backend: &str,
    max_batch: usize,
    mode: Mode,
    serial_oracle: bool,
    kv_dtype: KvDtype,
) -> anyhow::Result<RunResult> {
    let mut cfg = Config::from_preset(w.preset).map_err(anyhow::Error::msg)?;
    cfg.method = method;
    cfg.serve.backend = backend.into();
    cfg.serve.max_batch = max_batch;
    cfg.serve.kv_dtype = kv_dtype;
    cfg.calib.n_calib_seqs = w.calib_seqs;
    cfg.calib.calib_seq_len = w.calib_len;
    cfg.run_dir = format!(
        "runs/bench_e2e_{}_{}_{}_{}",
        w.preset,
        method.name(),
        backend,
        kv_dtype.name()
    );
    let mut engine = build_engine(&cfg)?;
    engine.set_serial_oracle(serial_oracle);
    let cache_per_tok = engine.cache_bytes_per_token();
    let mut router = Router::new(BatcherConfig::from(&cfg.serve));
    let corpus = Corpus::new(cfg.model.vocab_size, 99);
    let prompts: Vec<Vec<u32>> = (0..w.n_requests)
        .map(|i| corpus.sequence(Split::Validation, 2_000 + i as u64, w.prompt_len))
        .collect();

    let metrics = match mode {
        Mode::Offline => {
            for (i, prompt) in prompts.into_iter().enumerate() {
                router
                    .submit(&engine, Request::new(i as u64, prompt, w.gen_len))
                    .map_err(|e| anyhow::anyhow!("{e:?}"))?;
            }
            let done = router.run_offline(&mut engine)?;
            assert_eq!(done.len(), w.n_requests);
            router.metrics.clone()
        }
        Mode::Session => {
            let handle = router.serve(Box::new(engine));
            let submissions: Vec<RequestHandle> = prompts
                .into_iter()
                .enumerate()
                .map(|(i, prompt)| handle.submit(Request::new(i as u64, prompt, w.gen_len)))
                .collect();
            for rh in submissions {
                rh.wait()?;
            }
            let m = handle.metrics();
            handle.join()?;
            m
        }
    };

    let (_, _, ttft_p50, ttft_p95, ..) = metrics.summary_stats("ttft_ms").unwrap();
    let (_, tpot_mean, ..) = metrics.summary_stats("tpot_ms").unwrap();
    Ok(RunResult {
        decode_tok_per_s: metrics
            .gauge_value(metric_names::DECODE_TOK_PER_S)
            .unwrap_or(0.0),
        prefill_tok_per_s: metrics
            .gauge_value(metric_names::PREFILL_TOK_PER_S)
            .unwrap_or(0.0),
        ttft_p50,
        ttft_p95,
        tpot_mean,
        cache_per_tok,
        peak_bytes: metrics.gauge_value("cache_peak_bytes").unwrap_or(0.0) as u64,
    })
}

/// Long-prompt-interleave scenario: short-prompt decode streams must keep
/// emitting tokens while one long prompt prefills. Asserts the scheduler-v2
/// contract — fused steps actually overlapped the phases (`mixed_steps > 0`)
/// and decode never stalled (`decode_stall_steps == 0`).
fn long_prompt_interleave(smoke: bool) -> anyhow::Result<Json> {
    let (short_n, short_prompt, short_gen, long_prompt, long_gen) =
        if smoke { (4usize, 8usize, 24usize, 96usize, 4usize) } else { (8, 8, 48, 160, 8) };
    let mut cfg = Config::from_preset("test-tiny").map_err(anyhow::Error::msg)?;
    cfg.method = Method::KqSvd;
    cfg.calib.n_calib_seqs = 2;
    cfg.calib.calib_seq_len = 48;
    cfg.serve.max_batch = short_n + 1;
    cfg.serve.prefill_chunk = 16;
    cfg.run_dir = "runs/bench_e2e_interleave".into();
    let mut engine = build_engine(&cfg)?;
    let mut router = Router::new(BatcherConfig::from(&cfg.serve));
    let corpus = Corpus::new(cfg.model.vocab_size, 77);
    for i in 0..short_n {
        let prompt = corpus.sequence(Split::Validation, 3_000 + i as u64, short_prompt);
        router
            .submit(&engine, Request::new(i as u64, prompt, short_gen))
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
    }
    let long = corpus.sequence(Split::Validation, 4_000, long_prompt);
    router
        .submit(&engine, Request::new(short_n as u64, long, long_gen))
        .map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let done = router.run_offline(&mut engine)?;
    anyhow::ensure!(done.len() == short_n + 1, "all requests must complete");

    let m = &router.metrics;
    let mixed = m.counter(metric_names::MIXED_STEPS);
    let stalls = m.counter(metric_names::DECODE_STALL_STEPS);
    let (_, prefill_per_step_mean, ..) = m
        .summary_stats(metric_names::PREFILL_TOKENS_PER_STEP)
        .unwrap_or((0, 0.0, 0.0, 0.0, 0.0, 0.0));
    println!(
        "\nlong-prompt interleave ({} short streams × {short_gen} gen + 1×{long_prompt}-token prompt):",
        short_n
    );
    println!(
        "  mixed prefill+decode steps: {mixed} · decode-stall steps: {stalls} · {:.1} prefill tok/step",
        prefill_per_step_mean
    );
    anyhow::ensure!(
        mixed > 0,
        "scheduler never overlapped prefill with decode (mixed_steps == 0)"
    );
    anyhow::ensure!(
        stalls == 0,
        "decode stalled during prefill on {stalls} steps — the head-of-line \
         blocking scheduler v2 removes"
    );
    Ok(Json::obj()
        .set("short_streams", short_n)
        .set("short_prompt_len", short_prompt)
        .set("short_gen_len", short_gen)
        .set("long_prompt_len", long_prompt)
        .set("mixed_steps", mixed)
        .set("decode_stall_steps", stalls)
        .set("prefill_tokens_per_step_mean", prefill_per_step_mean)
        .set(
            "decode_tok_per_s",
            m.gauge_value(metric_names::DECODE_TOK_PER_S).unwrap_or(0.0),
        ))
}

/// Preemption scenario: two priority-0 streams hold the whole budget and run
/// mid-generation; a priority-1 request submitted afterwards must be
/// admitted by evicting a victim (preemptions > 0) and every request —
/// including the resumed victim — must still complete. Drives the batcher
/// directly so the high-priority request genuinely arrives *after* the
/// victims started decoding (an offline drain would admit it first).
fn preemption_under_pressure() -> anyhow::Result<Json> {
    use kqsvd::coordinator::Batcher;
    let mut cfg = Config::from_preset("test-tiny").map_err(anyhow::Error::msg)?;
    cfg.method = Method::KqSvd;
    cfg.calib.n_calib_seqs = 2;
    cfg.calib.calib_seq_len = 48;
    cfg.serve.max_batch = 4;
    cfg.serve.prefill_chunk = 16;
    cfg.run_dir = "runs/bench_e2e_preemption".into();
    let mut engine = build_engine(&cfg)?;
    // Budget fits exactly two 16-token reservations.
    let budget = engine.cache.bytes_for_tokens(16) * 2;
    engine.cache =
        kqsvd::kvcache::KvCacheManager::new(engine.cache.spec().clone(), budget);
    let mut b = Batcher::new(BatcherConfig::from(&cfg.serve));
    let corpus = Corpus::new(cfg.model.vocab_size, 78);
    for i in 0..2u64 {
        let prompt = corpus.sequence(Split::Validation, 5_000 + i, 8);
        b.submit(&engine, Request::new(i, prompt, 8))
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
    }
    // Let both priority-0 streams prefill and decode past the preemption
    // cooldown before the high-priority request arrives.
    let mut done = Vec::new();
    for _ in 0..6 {
        b.step(&mut engine)?;
        done.append(&mut b.take_completions());
    }
    let mut hi = GenParams::greedy(8);
    hi.priority = 1;
    let prompt = corpus.sequence(Split::Validation, 5_100, 8);
    b.submit(&engine, Request::with_params(2, prompt, hi))
        .map_err(|e| anyhow::anyhow!("{e:?}"))?;
    done.append(&mut b.run_to_completion(&mut engine)?);
    anyhow::ensure!(done.len() == 3, "victims must resume and complete");
    let preemptions = b.preempted();
    anyhow::ensure!(
        preemptions > 0,
        "the priority-1 request must be admitted by preemption"
    );
    println!(
        "preemption under pressure: {preemptions} preemption(s), all {} requests completed",
        done.len()
    );
    Ok(Json::obj()
        .set("preemptions", preemptions)
        .set("completed", done.len()))
}

/// Drive the batcher to idle, tracking the pool's peak physical bytes and
/// the prefix-cache hit tokens reported by `StepOutcome`.
fn drain_tracking(b: &mut Batcher, engine: &mut ServingEngine) -> anyhow::Result<(u64, usize)> {
    let mut peak_used = 0u64;
    let mut hits = 0usize;
    let mut idle_streak = 0usize;
    while !b.idle() {
        let out = b.step(engine)?;
        if let StepOutcome::Step { prefix_hit_tokens, .. } = out {
            hits += prefix_hit_tokens;
        }
        b.check_progress(&out, &mut idle_streak)?;
        peak_used = peak_used.max(engine.cache.used_bytes());
        b.take_completions();
    }
    Ok((peak_used, hits))
}

/// Shared-system-prompt scenario (satellite): N concurrent requests with a
/// common 256-token prefix through the shared-page pool. Asserts prefix
/// hits > 0 and pool `used_bytes` growing **sublinearly** in the number of
/// concurrent same-prefix sequences (shared bytes are charged once), and
/// records `prefix_hit_tokens` + effective bytes/token in
/// `BENCH_serving.json`.
fn shared_prefix_scenario(smoke: bool) -> anyhow::Result<Json> {
    let n = if smoke { 4usize } else { 8 };
    let (prefix_len, suffix_len, gen_len) = (256usize, 8usize, 4usize);
    let mut cfg = Config::from_preset("mha-small").map_err(anyhow::Error::msg)?;
    cfg.method = Method::KqSvd;
    cfg.calib.n_calib_seqs = 2;
    cfg.calib.calib_seq_len = 64;
    cfg.serve.max_batch = n;
    cfg.serve.prefill_chunk = 64;
    cfg.serve.prefix_cache = true;
    cfg.run_dir = "runs/bench_e2e_shared_prefix".into();
    let mut engine = build_engine(&cfg)?;
    let corpus = Corpus::new(cfg.model.vocab_size, 79);
    let prefix = corpus.sequence(Split::Validation, 6_000, prefix_len);
    let prompt_for = |i: u64| {
        let mut p = prefix.clone();
        p.extend(corpus.sequence(Split::Validation, 6_100 + i, suffix_len));
        p
    };

    let mut b = Batcher::new(BatcherConfig::from(&cfg.serve));
    // Warm pass: one request runs alone, registering the prefix chunks.
    b.submit(&engine, Request::new(0, prompt_for(0), gen_len))
        .map_err(|e| anyhow::anyhow!("{e:?}"))?;
    drain_tracking(&mut b, &mut engine)?;
    let warm_bytes = engine.cache.used_bytes(); // the now-cold cached prefix

    // Concurrent pass: N same-prefix requests in flight together.
    for i in 1..=n as u64 {
        b.submit(&engine, Request::new(i, prompt_for(i), gen_len))
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
    }
    let (peak_used, hit_tokens) = drain_tracking(&mut b, &mut engine)?;
    anyhow::ensure!(hit_tokens > 0, "same-prefix requests must hit the prefix cache");
    anyhow::ensure!(
        hit_tokens >= n * prefix_len,
        "every concurrent request should map the whole prefix ({hit_tokens} hit tokens)"
    );
    let naive = engine.cache.bytes_for_tokens(prefix_len + suffix_len + gen_len) * n as u64;
    anyhow::ensure!(
        peak_used < engine.cache.bytes_for_tokens(prefix_len) * 2,
        "pool bytes must grow sublinearly in same-prefix sequences: \
         {n} concurrent sequences peaked at {peak_used} B"
    );
    let total_tokens = (n * (prefix_len + suffix_len + gen_len)) as f64;
    let eff_bytes_per_token = peak_used as f64 / total_tokens;
    println!(
        "\nshared-prefix scenario ({n} requests × {prefix_len}-token common prefix + {suffix_len} suffix):"
    );
    println!(
        "  prefix hit tokens: {hit_tokens} · peak pool {} (naive per-seq {}) · {:.1} effective B/token",
        fmt_bytes(peak_used),
        fmt_bytes(naive),
        eff_bytes_per_token
    );
    Ok(Json::obj()
        .set("n_requests", n)
        .set("prefix_len", prefix_len)
        .set("suffix_len", suffix_len)
        .set("gen_len", gen_len)
        .set("prefix_hit_tokens", hit_tokens)
        .set("warm_prefix_bytes", warm_bytes)
        .set("peak_pool_bytes", peak_used)
        .set("naive_unshared_bytes", naive)
        .set("effective_bytes_per_token", eff_bytes_per_token)
        .set(
            "bytes_per_token_unshared",
            engine.cache_bytes_per_token(),
        ))
}

/// Quantized-vs-f32 scenario (tentpole): the same kqsvd workload at batch 8
/// with f32 vs int8 page storage. Asserts the int8 spec shrinks bytes/token
/// by ≥ 3.5× (the acceptance floor; per-row int8+scale gives `Σ4w/Σ(w+1)`)
/// and records decode tok/s + bytes/token for both modes in
/// `BENCH_serving.json`.
fn quantized_vs_f32(smoke: bool) -> anyhow::Result<Json> {
    let w = if smoke {
        Workload {
            preset: "mha-small",
            n_requests: 4,
            prompt_len: 32,
            gen_len: 8,
            calib_seqs: 2,
            calib_len: 64,
        }
    } else {
        Workload {
            preset: "mha-small",
            n_requests: 8,
            prompt_len: 64,
            gen_len: 16,
            calib_seqs: 4,
            calib_len: 128,
        }
    };
    let f32_r = run(&w, Method::KqSvd, "rust", 8, Mode::Offline, false, KvDtype::F32)?;
    let i8_r = run(&w, Method::KqSvd, "rust", 8, Mode::Offline, false, KvDtype::Int8)?;
    let ratio = f32_r.cache_per_tok as f64 / i8_r.cache_per_tok as f64;
    println!("\nquantized vs f32 cache ({}, batch 8, method kqsvd):", w.preset);
    println!(
        "  f32 : decode {:.1} tok/s · {} /token · peak {}",
        f32_r.decode_tok_per_s,
        fmt_bytes(f32_r.cache_per_tok),
        fmt_bytes(f32_r.peak_bytes)
    );
    println!(
        "  int8: decode {:.1} tok/s · {} /token · peak {}",
        i8_r.decode_tok_per_s,
        fmt_bytes(i8_r.cache_per_tok),
        fmt_bytes(i8_r.peak_bytes)
    );
    println!("  bytes/token ratio: {ratio:.2}× (target ≥ 3.5×)");
    anyhow::ensure!(
        ratio >= 3.5,
        "int8 bytes/token reduction {ratio:.2}× is below the 3.5× acceptance floor"
    );
    anyhow::ensure!(
        i8_r.peak_bytes < f32_r.peak_bytes,
        "int8 peak cache must be smaller"
    );
    Ok(Json::obj()
        .set("preset", w.preset)
        .set("n_requests", w.n_requests)
        .set("prompt_len", w.prompt_len)
        .set("gen_len", w.gen_len)
        .set("f32_decode_tok_per_s", f32_r.decode_tok_per_s)
        .set("int8_decode_tok_per_s", i8_r.decode_tok_per_s)
        .set("f32_bytes_per_token", f32_r.cache_per_tok)
        .set("int8_bytes_per_token", i8_r.cache_per_tok)
        .set("bytes_per_token_ratio", ratio)
        .set("f32_peak_bytes", f32_r.peak_bytes)
        .set("int8_peak_bytes", i8_r.peak_bytes))
}

/// Fleet-scaling scenario (tentpole): the same shared-system-prompt workload
/// through 1, 2 (and 4 in full runs) engine replicas behind the
/// prefix-affinity fleet dispatcher. Four request groups each share a
/// system prompt (one global prefix would co-locate everything on one
/// replica and show no scaling), so the dispatcher spreads groups across
/// replicas while same-group requests chase their warm pages. Records
/// wall-clock aggregate decode throughput (total generated tokens / wall
/// seconds — summed engine-time rates would fake scaling on one core) and
/// the affinity hit rate per replica count; full runs on ≥4-core hosts gate
/// ≥1.6× aggregate throughput at 2 replicas vs 1.
fn fleet_scaling(smoke: bool) -> anyhow::Result<Json> {
    use kqsvd::coordinator::{Engine, Fleet, FleetConfig};
    use kqsvd::server::build_fleet;
    use std::time::Instant;

    let replica_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    let groups = 4usize;
    let (per_group, prefix_len, suffix_len, gen_len) = if smoke {
        (3usize, 32usize, 8usize, 8usize)
    } else {
        (6, 64, 8, 24)
    };
    let n_requests = groups * per_group;

    println!(
        "\nfleet scaling ({n_requests} requests in {groups} shared-prefix groups × \
         ({prefix_len} prefix + {suffix_len} suffix, gen {gen_len})):"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut tput: Vec<(usize, f64)> = Vec::new();
    for &replicas in replica_counts {
        let mut cfg = Config::from_preset("test-tiny").map_err(anyhow::Error::msg)?;
        cfg.method = Method::KqSvd;
        cfg.calib.n_calib_seqs = 2;
        cfg.calib.calib_seq_len = 48;
        cfg.serve.max_batch = 4;
        cfg.serve.prefill_chunk = 16;
        cfg.serve.replicas = replicas;
        // One run dir for every replica count: the fleet builder loads the
        // cached weights/projections after the first build.
        cfg.run_dir = "runs/bench_e2e_fleet".into();
        let engines = build_fleet(&cfg)?;
        let boxed: Vec<Box<dyn Engine + Send>> = engines
            .into_iter()
            .map(|e| Box::new(e) as Box<dyn Engine + Send>)
            .collect();
        let handle = Fleet::serve(
            FleetConfig::from(&cfg.serve),
            BatcherConfig::from(&cfg.serve),
            boxed,
        );
        let corpus = Corpus::new(cfg.model.vocab_size, 81);
        let t0 = Instant::now();
        let submissions: Vec<RequestHandle> = (0..n_requests)
            .map(|i| {
                let g = (i % groups) as u64;
                let mut p = corpus.sequence(Split::Validation, 7_000 + g, prefix_len);
                p.extend(corpus.sequence(Split::Validation, 7_100 + i as u64, suffix_len));
                handle.submit(Request::new(i as u64, p, gen_len))
            })
            .collect();
        let mut gen_tokens = 0usize;
        for rh in submissions {
            gen_tokens += rh.wait()?.tokens.len();
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let m = handle.metrics();
        handle.join()?;

        let hits = m.counter(metric_names::FLEET_AFFINITY_HITS);
        let misses = m.counter(metric_names::FLEET_AFFINITY_MISSES);
        let steals = m.counter(metric_names::FLEET_STEALS);
        anyhow::ensure!(
            hits + misses == n_requests as u64,
            "every submission must be classified hit or miss"
        );
        // At worst the first request of each group routes cold; followers
        // must chase their group's warm pages through the fingerprint index.
        anyhow::ensure!(
            hits >= (n_requests - groups) as u64,
            "affinity hit rate collapsed: {hits} hits / {misses} misses"
        );
        let hit_rate = hits as f64 / (hits + misses).max(1) as f64;
        let agg_tok_per_s = gen_tokens as f64 / wall_s.max(1e-9);
        println!(
            "  replicas {replicas}: {agg_tok_per_s:.1} aggregate decode tok/s \
             (wall {wall_s:.2}s) · {:.0}% affinity hits · {steals} steals",
            hit_rate * 100.0
        );
        rows.push(
            Json::obj()
                .set("replicas", replicas)
                .set("aggregate_decode_tok_per_s", agg_tok_per_s)
                .set(
                    "engine_decode_tok_per_s",
                    m.gauge_value(metric_names::DECODE_TOK_PER_S).unwrap_or(0.0),
                )
                .set("wall_s", wall_s)
                .set("affinity_hit_rate", hit_rate)
                .set("affinity_hits", hits)
                .set("affinity_misses", misses)
                .set("steals", steals),
        );
        tput.push((replicas, agg_tok_per_s));
    }

    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let at = |n: usize| tput.iter().find(|(r, _)| *r == n).map(|(_, t)| *t);
    let scaling_2x = match (at(1), at(2)) {
        (Some(t1), Some(t2)) => t2 / t1.max(1e-9),
        _ => 0.0,
    };
    println!("  2-replica scaling: {scaling_2x:.2}× (gate ≥ 1.6× on ≥4-core full runs; {cores} cores)");
    // Smoke runs and small hosts record the ratio without gating: CI
    // 2-core runners can't run two pump threads truly concurrently.
    if !smoke && cores >= 4 {
        anyhow::ensure!(
            scaling_2x >= 1.6,
            "2-replica aggregate decode scaling {scaling_2x:.2}× is below the 1.6× acceptance floor"
        );
    }
    Ok(Json::obj()
        .set("groups", groups)
        .set("n_requests", n_requests)
        .set("prefix_len", prefix_len)
        .set("gen_len", gen_len)
        .set("host_cores", cores)
        .set("scaling_2x", scaling_2x)
        .set("rows", Json::Arr(rows)))
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("KQSVD_BENCH_SMOKE")
        .map(|v| v == "1")
        .unwrap_or(false);
    let main_w = if smoke {
        Workload {
            preset: "mha-small",
            n_requests: 4,
            prompt_len: 32,
            gen_len: 8,
            calib_seqs: 2,
            calib_len: 64,
        }
    } else {
        Workload {
            preset: "mha-small",
            n_requests: 16,
            prompt_len: 96,
            gen_len: 32,
            calib_seqs: 8,
            calib_len: 256,
        }
    };
    println!(
        "E2E serving bench{}: {} requests × ({} prompt + {} gen), {}\n",
        if smoke { " (smoke)" } else { "" },
        main_w.n_requests,
        main_w.prompt_len,
        main_w.gen_len,
        main_w.preset,
    );
    let mut t = Table::new(&[
        "method", "backend", "mode", "batch", "decode tok/s", "prefill tok/s",
        "ttft p50(ms)", "ttft p95(ms)", "tpot(ms)", "cache/tok", "peak cache",
    ]);
    let mut main_rows: Vec<Json> = Vec::new();
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    for (method, backend) in [
        (Method::None, "rust"),
        (Method::KqSvd, "rust"),
        (Method::None, "pjrt"),
        (Method::KqSvd, "pjrt"),
    ] {
        if backend == "pjrt" && (!have_artifacts || smoke) {
            if !smoke {
                println!("  (skipping pjrt rows — run `make artifacts`)");
            }
            continue;
        }
        // The session rows only run on the rust backend: they measure
        // streaming overhead, which is backend-independent.
        let modes: &[Mode] = if backend == "rust" {
            &[Mode::Offline, Mode::Session]
        } else {
            &[Mode::Offline]
        };
        for batch in [1usize, 8] {
            for &mode in modes {
                let r = run(&main_w, method, backend, batch, mode, false, KvDtype::F32)?;
                t.row(&[
                    method.name().into(),
                    backend.into(),
                    mode.name().into(),
                    batch.to_string(),
                    fnum(r.decode_tok_per_s, 1),
                    fnum(r.prefill_tok_per_s, 1),
                    fnum(r.ttft_p50, 2),
                    fnum(r.ttft_p95, 2),
                    fnum(r.tpot_mean, 3),
                    fmt_bytes(r.cache_per_tok),
                    fmt_bytes(r.peak_bytes),
                ]);
                main_rows.push(
                    Json::obj()
                        .set("method", method.name())
                        .set("backend", backend)
                        .set("mode", mode.name())
                        .set("max_batch", batch)
                        .set("decode_tok_per_s", r.decode_tok_per_s)
                        .set("prefill_tok_per_s", r.prefill_tok_per_s)
                        .set("ttft_p50_ms", r.ttft_p50)
                        .set("ttft_p95_ms", r.ttft_p95)
                        .set("tpot_mean_ms", r.tpot_mean)
                        .set("cache_bytes_per_token", r.cache_per_tok)
                        .set("cache_peak_bytes", r.peak_bytes),
                );
            }
        }
    }
    t.print();
    t.write_csv("e2e_serving.csv")?;

    // Serial-vs-batch: the acceptance comparison for the batch-major GEMM
    // execution path, at batch 8 on the test-tiny preset.
    let tiny_w = Workload {
        preset: "test-tiny",
        n_requests: 16,
        prompt_len: 32,
        gen_len: 32,
        calib_seqs: 3,
        calib_len: 48,
    };
    println!("\nserial-vs-batch decode ({}, batch 8, method kqsvd):", tiny_w.preset);
    let serial = run(&tiny_w, Method::KqSvd, "rust", 8, Mode::Offline, true, KvDtype::F32)?;
    let batch = run(&tiny_w, Method::KqSvd, "rust", 8, Mode::Offline, false, KvDtype::F32)?;
    let speedup = batch.decode_tok_per_s / serial.decode_tok_per_s.max(1e-9);
    println!(
        "  serial oracle: decode {:.1} tok/s · prefill {:.1} tok/s",
        serial.decode_tok_per_s, serial.prefill_tok_per_s
    );
    println!(
        "  batch-major:   decode {:.1} tok/s · prefill {:.1} tok/s",
        batch.decode_tok_per_s, batch.prefill_tok_per_s
    );
    println!("  batch-major decode speedup: {speedup:.2}× (target ≥ 3×)");

    // Scheduler-v2 + shared-page-pool acceptance scenarios (assertions
    // inside; structural, so they run in smoke mode too).
    let interleave = long_prompt_interleave(smoke)?;
    let preemption = preemption_under_pressure()?;
    let shared_prefix = shared_prefix_scenario(smoke)?;
    let quantized = quantized_vs_f32(smoke)?;
    let fleet = fleet_scaling(smoke)?;

    let json = Json::obj()
        .set("bench", "e2e_serving")
        .set("smoke", smoke)
        .set(
            "workload",
            Json::obj()
                .set("preset", main_w.preset)
                .set("n_requests", main_w.n_requests)
                .set("prompt_len", main_w.prompt_len)
                .set("gen_len", main_w.gen_len),
        )
        .set("rows", Json::Arr(main_rows))
        .set(
            "serial_vs_batch",
            Json::obj()
                .set("preset", tiny_w.preset)
                .set("method", Method::KqSvd.name())
                .set("max_batch", 8usize)
                .set("n_requests", tiny_w.n_requests)
                .set("prompt_len", tiny_w.prompt_len)
                .set("gen_len", tiny_w.gen_len)
                .set("serial_decode_tok_per_s", serial.decode_tok_per_s)
                .set("serial_prefill_tok_per_s", serial.prefill_tok_per_s)
                .set("batch_decode_tok_per_s", batch.decode_tok_per_s)
                .set("batch_prefill_tok_per_s", batch.prefill_tok_per_s)
                .set("decode_speedup", speedup),
        )
        .set("long_prompt_interleave", interleave)
        .set("preemption_under_pressure", preemption)
        .set("shared_prefix", shared_prefix)
        .set("quantized_vs_f32", quantized)
        .set("fleet_scaling", fleet);
    std::fs::write("BENCH_serving.json", json.to_string_pretty())?;
    println!("\nCSV → bench_out/e2e_serving.csv · JSON → BENCH_serving.json");

    // Enforce the acceptance gate (recorded above regardless). Smoke mode is
    // advisory: 2-core CI runners make the ratio too noisy to fail on.
    if !smoke {
        anyhow::ensure!(
            speedup >= 3.0,
            "batch-major decode speedup {speedup:.2}× is below the 3× acceptance floor"
        );
    }
    Ok(())
}
