//! E2E — end-to-end serving benchmark: throughput, latency and cache bytes,
//! exact vs KQ-SVD-compressed cache, through the full router/batcher stack.
//! Adds a batch-size sweep (the serving-side payoff of cache compression:
//! more sequences fit in the same budget).
//!
//! Run: `cargo bench --bench e2e_serving`  (PJRT row needs `make artifacts`)

use kqsvd::bench_support::{f as fnum, Table};
use kqsvd::config::{Config, Method};
use kqsvd::coordinator::{BatcherConfig, Request, Router};
use kqsvd::server::build_engine;
use kqsvd::text::{Corpus, Split};
use kqsvd::util::stats::fmt_bytes;

struct RunResult {
    tok_per_s: f64,
    ttft_p50: f64,
    ttft_p95: f64,
    tpot_mean: f64,
    cache_per_tok: usize,
    peak_bytes: u64,
}

fn run(method: Method, backend: &str, max_batch: usize, n_requests: usize) -> anyhow::Result<RunResult> {
    let mut cfg = Config::from_preset("mha-small").map_err(anyhow::Error::msg)?;
    cfg.method = method;
    cfg.serve.backend = backend.into();
    cfg.serve.max_batch = max_batch;
    cfg.calib.n_calib_seqs = 8;
    cfg.calib.calib_seq_len = 256;
    cfg.run_dir = format!("runs/bench_e2e_{}_{}", method.name(), backend);
    let mut engine = build_engine(&cfg)?;
    let mut router = Router::new(BatcherConfig::from(&cfg.serve));
    let corpus = Corpus::new(cfg.model.vocab_size, 99);
    for i in 0..n_requests {
        let prompt = corpus.sequence(Split::Validation, 2_000 + i as u64, 96);
        router
            .submit(&engine, Request::new(i as u64, prompt, 32))
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
    }
    let done = router.run_offline(&mut engine)?;
    assert_eq!(done.len(), n_requests);
    let m = &router.metrics;
    let (_, _, ttft_p50, ttft_p95, ..) = m.summary_stats("ttft_ms").unwrap();
    let (_, tpot_mean, ..) = m.summary_stats("tpot_ms").unwrap();
    Ok(RunResult {
        tok_per_s: m.gauge_value("decode_tok_per_s").unwrap_or(0.0),
        ttft_p50,
        ttft_p95,
        tpot_mean,
        cache_per_tok: engine.cache_bytes_per_token(),
        peak_bytes: engine.cache.peak_bytes(),
    })
}

fn main() -> anyhow::Result<()> {
    let n_requests = 16;
    println!("E2E serving bench: {n_requests} requests × (96 prompt + 32 gen), mha-small\n");
    let mut t = Table::new(&[
        "method", "backend", "batch", "tok/s", "ttft p50(ms)", "ttft p95(ms)", "tpot(ms)",
        "cache/tok", "peak cache",
    ]);
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let mut comp_vs_exact = (0.0f64, 0.0f64);
    for (method, backend) in [
        (Method::None, "rust"),
        (Method::KqSvd, "rust"),
        (Method::None, "pjrt"),
        (Method::KqSvd, "pjrt"),
    ] {
        if backend == "pjrt" && !have_artifacts {
            println!("  (skipping pjrt rows — run `make artifacts`)");
            continue;
        }
        for batch in [1usize, 8] {
            let r = run(method, backend, batch, n_requests)?;
            if backend == "rust" && batch == 8 {
                if method == Method::None {
                    comp_vs_exact.0 = r.tok_per_s;
                } else {
                    comp_vs_exact.1 = r.tok_per_s;
                }
            }
            t.row(&[
                method.name().into(),
                backend.into(),
                batch.to_string(),
                fnum(r.tok_per_s, 1),
                fnum(r.ttft_p50, 2),
                fnum(r.ttft_p95, 2),
                fnum(r.tpot_mean, 3),
                fmt_bytes(r.cache_per_tok as u64),
                fmt_bytes(r.peak_bytes),
            ]);
        }
    }
    t.print();
    t.write_csv("e2e_serving.csv")?;
    let (exact, comp) = comp_vs_exact;
    println!(
        "\ncompressed/exact decode throughput at batch 8 (rust): {:.2}×",
        comp / exact.max(1e-9)
    );
    println!("CSV → bench_out/e2e_serving.csv");
    Ok(())
}
