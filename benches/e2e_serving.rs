//! E2E — end-to-end serving benchmark: throughput, latency and cache bytes,
//! exact vs KQ-SVD-compressed cache, through the full router/batcher stack.
//! Covers both serving modes — offline drain (`Router::run_offline`) and the
//! streaming session API (`Router::serve` + `EngineHandle`) — which share
//! one scheduling path, so the delta between the rows is pure session
//! overhead (channels + engine thread) — plus a **serial-vs-batch** section
//! comparing the batch-major GEMM execution path against the serial
//! `forward_token` oracle on the `test-tiny` preset.
//!
//! Results are printed as a table, written to `bench_out/e2e_serving.csv`,
//! and summarized into `BENCH_serving.json` at the repository root so the
//! perf trajectory is machine-readable across PRs.
//!
//! Run: `cargo bench --bench e2e_serving`  (PJRT row needs `make artifacts`)
//! CI smoke mode: `KQSVD_BENCH_SMOKE=1 cargo bench --bench e2e_serving`
//! shrinks calibration and the request count so the job finishes quickly.

use kqsvd::bench_support::{f as fnum, Table};
use kqsvd::config::{Config, Method};
use kqsvd::coordinator::metrics::names as metric_names;
use kqsvd::coordinator::{BatcherConfig, Request, RequestHandle, Router};
use kqsvd::jsonutil::Json;
use kqsvd::server::build_engine;
use kqsvd::text::{Corpus, Split};
use kqsvd::util::stats::fmt_bytes;

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Offline,
    Session,
}

impl Mode {
    fn name(self) -> &'static str {
        match self {
            Mode::Offline => "offline",
            Mode::Session => "session",
        }
    }
}

struct RunResult {
    decode_tok_per_s: f64,
    prefill_tok_per_s: f64,
    ttft_p50: f64,
    ttft_p95: f64,
    tpot_mean: f64,
    cache_per_tok: usize,
    peak_bytes: u64,
}

struct Workload {
    preset: &'static str,
    n_requests: usize,
    prompt_len: usize,
    gen_len: usize,
    calib_seqs: usize,
    calib_len: usize,
}

fn run(
    w: &Workload,
    method: Method,
    backend: &str,
    max_batch: usize,
    mode: Mode,
    serial_oracle: bool,
) -> anyhow::Result<RunResult> {
    let mut cfg = Config::from_preset(w.preset).map_err(anyhow::Error::msg)?;
    cfg.method = method;
    cfg.serve.backend = backend.into();
    cfg.serve.max_batch = max_batch;
    cfg.calib.n_calib_seqs = w.calib_seqs;
    cfg.calib.calib_seq_len = w.calib_len;
    cfg.run_dir = format!("runs/bench_e2e_{}_{}_{}", w.preset, method.name(), backend);
    let mut engine = build_engine(&cfg)?;
    engine.set_serial_oracle(serial_oracle);
    let cache_per_tok = engine.cache_bytes_per_token();
    let mut router = Router::new(BatcherConfig::from(&cfg.serve));
    let corpus = Corpus::new(cfg.model.vocab_size, 99);
    let prompts: Vec<Vec<u32>> = (0..w.n_requests)
        .map(|i| corpus.sequence(Split::Validation, 2_000 + i as u64, w.prompt_len))
        .collect();

    let metrics = match mode {
        Mode::Offline => {
            for (i, prompt) in prompts.into_iter().enumerate() {
                router
                    .submit(&engine, Request::new(i as u64, prompt, w.gen_len))
                    .map_err(|e| anyhow::anyhow!("{e:?}"))?;
            }
            let done = router.run_offline(&mut engine)?;
            assert_eq!(done.len(), w.n_requests);
            router.metrics.clone()
        }
        Mode::Session => {
            let handle = router.serve(Box::new(engine));
            let submissions: Vec<RequestHandle> = prompts
                .into_iter()
                .enumerate()
                .map(|(i, prompt)| handle.submit(Request::new(i as u64, prompt, w.gen_len)))
                .collect();
            for rh in submissions {
                rh.wait()?;
            }
            let m = handle.metrics();
            handle.join()?;
            m
        }
    };

    let (_, _, ttft_p50, ttft_p95, ..) = metrics.summary_stats("ttft_ms").unwrap();
    let (_, tpot_mean, ..) = metrics.summary_stats("tpot_ms").unwrap();
    Ok(RunResult {
        decode_tok_per_s: metrics
            .gauge_value(metric_names::DECODE_TOK_PER_S)
            .unwrap_or(0.0),
        prefill_tok_per_s: metrics
            .gauge_value(metric_names::PREFILL_TOK_PER_S)
            .unwrap_or(0.0),
        ttft_p50,
        ttft_p95,
        tpot_mean,
        cache_per_tok,
        peak_bytes: metrics.gauge_value("cache_peak_bytes").unwrap_or(0.0) as u64,
    })
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("KQSVD_BENCH_SMOKE")
        .map(|v| v == "1")
        .unwrap_or(false);
    let main_w = if smoke {
        Workload {
            preset: "mha-small",
            n_requests: 4,
            prompt_len: 32,
            gen_len: 8,
            calib_seqs: 2,
            calib_len: 64,
        }
    } else {
        Workload {
            preset: "mha-small",
            n_requests: 16,
            prompt_len: 96,
            gen_len: 32,
            calib_seqs: 8,
            calib_len: 256,
        }
    };
    println!(
        "E2E serving bench{}: {} requests × ({} prompt + {} gen), {}\n",
        if smoke { " (smoke)" } else { "" },
        main_w.n_requests,
        main_w.prompt_len,
        main_w.gen_len,
        main_w.preset,
    );
    let mut t = Table::new(&[
        "method", "backend", "mode", "batch", "decode tok/s", "prefill tok/s",
        "ttft p50(ms)", "ttft p95(ms)", "tpot(ms)", "cache/tok", "peak cache",
    ]);
    let mut main_rows: Vec<Json> = Vec::new();
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    for (method, backend) in [
        (Method::None, "rust"),
        (Method::KqSvd, "rust"),
        (Method::None, "pjrt"),
        (Method::KqSvd, "pjrt"),
    ] {
        if backend == "pjrt" && (!have_artifacts || smoke) {
            if !smoke {
                println!("  (skipping pjrt rows — run `make artifacts`)");
            }
            continue;
        }
        // The session rows only run on the rust backend: they measure
        // streaming overhead, which is backend-independent.
        let modes: &[Mode] = if backend == "rust" {
            &[Mode::Offline, Mode::Session]
        } else {
            &[Mode::Offline]
        };
        for batch in [1usize, 8] {
            for &mode in modes {
                let r = run(&main_w, method, backend, batch, mode, false)?;
                t.row(&[
                    method.name().into(),
                    backend.into(),
                    mode.name().into(),
                    batch.to_string(),
                    fnum(r.decode_tok_per_s, 1),
                    fnum(r.prefill_tok_per_s, 1),
                    fnum(r.ttft_p50, 2),
                    fnum(r.ttft_p95, 2),
                    fnum(r.tpot_mean, 3),
                    fmt_bytes(r.cache_per_tok as u64),
                    fmt_bytes(r.peak_bytes),
                ]);
                main_rows.push(
                    Json::obj()
                        .set("method", method.name())
                        .set("backend", backend)
                        .set("mode", mode.name())
                        .set("max_batch", batch)
                        .set("decode_tok_per_s", r.decode_tok_per_s)
                        .set("prefill_tok_per_s", r.prefill_tok_per_s)
                        .set("ttft_p50_ms", r.ttft_p50)
                        .set("ttft_p95_ms", r.ttft_p95)
                        .set("tpot_mean_ms", r.tpot_mean)
                        .set("cache_bytes_per_token", r.cache_per_tok)
                        .set("cache_peak_bytes", r.peak_bytes),
                );
            }
        }
    }
    t.print();
    t.write_csv("e2e_serving.csv")?;

    // Serial-vs-batch: the acceptance comparison for the batch-major GEMM
    // execution path, at batch 8 on the test-tiny preset.
    let tiny_w = Workload {
        preset: "test-tiny",
        n_requests: 16,
        prompt_len: 32,
        gen_len: 32,
        calib_seqs: 3,
        calib_len: 48,
    };
    println!("\nserial-vs-batch decode ({}, batch 8, method kqsvd):", tiny_w.preset);
    let serial = run(&tiny_w, Method::KqSvd, "rust", 8, Mode::Offline, true)?;
    let batch = run(&tiny_w, Method::KqSvd, "rust", 8, Mode::Offline, false)?;
    let speedup = batch.decode_tok_per_s / serial.decode_tok_per_s.max(1e-9);
    println!(
        "  serial oracle: decode {:.1} tok/s · prefill {:.1} tok/s",
        serial.decode_tok_per_s, serial.prefill_tok_per_s
    );
    println!(
        "  batch-major:   decode {:.1} tok/s · prefill {:.1} tok/s",
        batch.decode_tok_per_s, batch.prefill_tok_per_s
    );
    println!("  batch-major decode speedup: {speedup:.2}× (target ≥ 3×)");

    let json = Json::obj()
        .set("bench", "e2e_serving")
        .set("smoke", smoke)
        .set(
            "workload",
            Json::obj()
                .set("preset", main_w.preset)
                .set("n_requests", main_w.n_requests)
                .set("prompt_len", main_w.prompt_len)
                .set("gen_len", main_w.gen_len),
        )
        .set("rows", Json::Arr(main_rows))
        .set(
            "serial_vs_batch",
            Json::obj()
                .set("preset", tiny_w.preset)
                .set("method", Method::KqSvd.name())
                .set("max_batch", 8usize)
                .set("n_requests", tiny_w.n_requests)
                .set("prompt_len", tiny_w.prompt_len)
                .set("gen_len", tiny_w.gen_len)
                .set("serial_decode_tok_per_s", serial.decode_tok_per_s)
                .set("serial_prefill_tok_per_s", serial.prefill_tok_per_s)
                .set("batch_decode_tok_per_s", batch.decode_tok_per_s)
                .set("batch_prefill_tok_per_s", batch.prefill_tok_per_s)
                .set("decode_speedup", speedup),
        );
    std::fs::write("BENCH_serving.json", json.to_string_pretty())?;
    println!("\nCSV → bench_out/e2e_serving.csv · JSON → BENCH_serving.json");

    // Enforce the acceptance gate (recorded above regardless). Smoke mode is
    // advisory: 2-core CI runners make the ratio too noisy to fail on.
    if !smoke {
        anyhow::ensure!(
            speedup >= 3.0,
            "batch-major decode speedup {speedup:.2}× is below the 3× acceptance floor"
        );
    }
    Ok(())
}
