//! MICRO — §Perf microbenchmarks for the hot paths of every layer:
//! matmul GFLOP/s, SVD latency, paged online-softmax attention throughput,
//! engine decode-step latency, and scheduler overhead.
//!
//! Run: `cargo bench --bench microbench`

use kqsvd::attn::online_attn;
use kqsvd::bench_support::{bench, f as fnum, Table};
use kqsvd::config::{Config, Method};
use kqsvd::coordinator::Engine;
use kqsvd::kvcache::{BlockTable, PagePool};
use kqsvd::linalg::{Mat, Svd};
use kqsvd::server::build_engine;
use kqsvd::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let mut report = Table::new(&["benchmark", "metric", "value"]);

    // --- L3 substrate: matmul --------------------------------------------
    println!("matmul:");
    for n in [128usize, 256, 512] {
        let mut rng = Pcg64::new(n as u64, 1);
        let a = Mat::randn(n, n, 1.0, &mut rng);
        let b = Mat::randn(n, n, 1.0, &mut rng);
        let m = bench(&format!("matmul {n}x{n}x{n}"), 2, 10, || {
            std::hint::black_box(a.matmul(&b));
        });
        let gflops = 2.0 * (n as f64).powi(3) / m.min_s / 1e9;
        report.row(&[format!("matmul_{n}"), "GFLOP/s".into(), fnum(gflops, 2)]);
    }

    // --- SVD (calibration kernel) ----------------------------------------
    println!("\nSVD (QR + one-sided Jacobi, f64):");
    for (t, d) in [(4096usize, 32usize), (4096, 64), (16384, 64)] {
        let mut rng = Pcg64::new((t + d) as u64, 2);
        let a = Mat::randn(t, d, 1.0, &mut rng);
        let m = bench(&format!("svd {t}x{d}"), 1, 3, || {
            std::hint::black_box(Svd::compute(&a));
        });
        report.row(&[format!("svd_{t}x{d}"), "ms".into(), fnum(m.mean_s * 1e3, 1)]);
    }

    // --- compressed attention kernel (Rust twin of the Pallas L1) ---------
    println!("\nonline-softmax compressed attention (per query):");
    for (t, r) in [(512usize, 16usize), (2048, 16), (2048, 32)] {
        let mut rng = Pcg64::new((t * r) as u64, 3);
        let ck_m = Mat::randn(t, r, 1.0, &mut rng);
        let cv_m = Mat::randn(t, r, 1.0, &mut rng);
        let mut pool = PagePool::new(16);
        let mut ck = BlockTable::new(r);
        let mut cv = BlockTable::new(r);
        for i in 0..t {
            pool.push_row(&mut ck, ck_m.row(i));
            pool.push_row(&mut cv, cv_m.row(i));
        }
        let q: Vec<f32> = (0..r).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let m = bench(&format!("online_attn T={t} R={r}"), 10, 50, || {
            std::hint::black_box(online_attn(&q, &pool, &ck, &cv, 0.125));
        });
        // Bytes streamed per call: T·(R+R)·4.
        let gbs = (t * r * 2 * 4) as f64 / m.min_s / 1e9;
        report.row(&[
            format!("online_attn_T{t}_R{r}"),
            "GB/s streamed".into(),
            fnum(gbs, 2),
        ]);
    }

    // --- engine decode step ------------------------------------------------
    println!("\nengine decode step (mha-small, rust backend):");
    let mut cfg = Config::from_preset("mha-small").map_err(anyhow::Error::msg)?;
    cfg.method = Method::KqSvd;
    cfg.calib.n_calib_seqs = 8;
    cfg.calib.calib_seq_len = 256;
    cfg.run_dir = "runs/bench_micro".into();
    let mut engine = build_engine(&cfg)?;
    engine.alloc(1, 640).unwrap();
    // Prefill 128 tokens of context.
    let prompt: Vec<u32> = (0..128).map(|i| (i % 60 + 1) as u32).collect();
    engine.prefill(1, &prompt, 0, true)?;
    let mut step = 0u32;
    let m = bench("decode_step ctx≈128", 3, 30, || {
        step = (step + 1) % 60;
        std::hint::black_box(engine.decode(&[(1, step + 1)]).unwrap());
    });
    report.row(&["decode_step_ctx128".into(), "ms".into(), fnum(m.mean_s * 1e3, 3)]);
    report.row(&[
        "decode_step_ctx128".into(),
        "tok/s (batch 1)".into(),
        fnum(1.0 / m.mean_s, 1),
    ]);

    // --- scheduler overhead (mock engine, no model math) -------------------
    println!("\nscheduler overhead:");
    {
        use kqsvd::coordinator::{BatcherConfig, Request, Router};
        let m = bench("router 64 reqs (mock-free math via tiny model)", 1, 3, || {
            let mut cfg = Config::from_preset("test-tiny").unwrap();
            cfg.method = Method::KqSvd;
            cfg.calib.n_calib_seqs = 2;
            cfg.calib.calib_seq_len = 32;
            cfg.run_dir = "runs/bench_micro_tiny".into();
            let mut eng = build_engine(&cfg).unwrap();
            let mut router = Router::new(BatcherConfig {
                max_batch: 8,
                max_queue: 128,
                prefill_chunk: 16,
                ..Default::default()
            });
            for i in 0..64 {
                router
                    .submit(&eng, Request::new(i, vec![1, 2, 3, 4], 4))
                    .unwrap();
            }
            std::hint::black_box(router.run_offline(&mut eng).unwrap());
        });
        report.row(&["router_64req_tiny".into(), "ms".into(), fnum(m.mean_s * 1e3, 1)]);
    }

    println!("\nsummary:");
    report.print();
    report.write_csv("microbench.csv")?;
    println!("CSV → bench_out/microbench.csv");
    Ok(())
}
