//! MICRO — §Perf microbenchmarks for the hot paths of every layer:
//! matmul GFLOP/s, SVD latency, paged online-softmax attention throughput,
//! engine decode-step latency, scheduler overhead, and a **per-kernel
//! scalar-vs-SIMD A/B harness** over the dispatched primitives (dot,
//! dequant-dot, axpy, online-attn step, paged GEMM tile) at rank widths
//! 16/24/64/100 — covering both lane-multiple and remainder-lane shapes.
//! The kernel section writes `BENCH_kernels.json` at the repository root
//! (ns/elem per tier + speedup ratios) so the SIMD win is machine-readable
//! across PRs, next to `BENCH_serving.json`.
//!
//! Run: `cargo bench --bench microbench`
//! CI smoke mode: `KQSVD_BENCH_SMOKE=1 cargo bench --bench microbench`
//! shrinks the slow sections (SVD, decode) so the job finishes quickly; the
//! kernel A/B still runs (fewer iters) so `BENCH_kernels.json` is always
//! produced. Outside smoke mode, the harness asserts the acceptance floor:
//! ≥2× SIMD-over-scalar on the fused dequant-dot when a SIMD tier is active.

use kqsvd::attn::{matmul_nt_paged_with, online_attn, online_attn_into_with};
use kqsvd::bench_support::{bench, f as fnum, Table};
use kqsvd::config::{Config, Method};
use kqsvd::coordinator::Engine;
use kqsvd::jsonutil::Json;
use kqsvd::kvcache::{BlockTable, KvDtype, PagePool};
use kqsvd::linalg::simd::{simd_table, KernelDispatch, SCALAR};
use kqsvd::linalg::{Mat, Svd};
use kqsvd::server::build_engine;
use kqsvd::util::rng::Pcg64;

/// One A/B cell: ns/elem for a kernel closure at one width under one tier.
/// `work(..)` must consume `elems` elements per call; repeats keep the
/// timed region well above timer resolution even for tiny widths.
fn ns_per_elem(name: &str, smoke: bool, elems: usize, mut work: impl FnMut()) -> f64 {
    let (warmup, iters) = if smoke { (2, 5) } else { (10, 40) };
    let m = bench(name, warmup, iters, &mut work);
    m.min_s * 1e9 / elems as f64
}

/// Scalar-vs-SIMD harness over every dispatched kernel shape. Returns the
/// JSON summary plus the best dequant-dot speedup (acceptance gate).
fn kernel_ab_section(report: &mut Table, smoke: bool) -> (Json, f64) {
    let tiers: Vec<&'static KernelDispatch> = match simd_table() {
        Some(t) => vec![&SCALAR, t],
        None => vec![&SCALAR],
    };
    let isa = simd_table().map(|t| t.isa).unwrap_or("none");
    println!("\nper-kernel scalar-vs-SIMD A/B (active SIMD tier: {isa}):");

    // Streaming geometry: T rows of width r, like one head's cache pass.
    let t_rows = if smoke { 256 } else { 2048 };
    let mut results = Json::obj().set("simd_isa", isa).set("smoke", smoke);
    let mut best_dequant_speedup = 0.0f64;

    for r in [16usize, 24, 64, 100] {
        let mut rng = Pcg64::new(r as u64, 7);
        let rows = Mat::randn(t_rows, r, 1.0, &mut rng);
        let x: Vec<f32> = (0..r).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let q_rows: Vec<Vec<i8>> = (0..t_rows)
            .map(|i| rows.row(i).iter().map(|&v| (v * 32.0) as i8).collect())
            .collect();
        let mut acc = vec![0.0f32; r];
        let elems = t_rows * r;

        // Paged caches for the composite kernels (f32 + int8 pools).
        let mut fpool = PagePool::new(16);
        let mut ipool = PagePool::with_dtype(16, KvDtype::Int8);
        let mut fk = BlockTable::new(r);
        let mut fv = BlockTable::new(r);
        let mut ik = BlockTable::new(r);
        for i in 0..t_rows {
            fpool.push_row(&mut fk, rows.row(i));
            fpool.push_row(&mut fv, rows.row(i));
            ipool.push_row(&mut ik, rows.row(i));
        }
        let qtile = Mat::randn(8, r, 1.0, &mut rng);
        let mut tile_out = Mat::zeros(0, 0);

        let mut width_json = Json::obj();
        for kernel in ["dot_f32", "dequant_dot_i8", "axpy_f32", "online_attn", "paged_gemm_tile"] {
            let mut per_tier: Vec<(String, f64)> = Vec::new();
            for ks in &tiers {
                let label = format!("{kernel} r={r} [{}]", ks.isa);
                let ns = match kernel {
                    "dot_f32" => ns_per_elem(&label, smoke, elems, || {
                        let mut s = 0.0f32;
                        for i in 0..t_rows {
                            s += (ks.dot_f32)(rows.row(i), &x);
                        }
                        std::hint::black_box(s);
                    }),
                    "dequant_dot_i8" => ns_per_elem(&label, smoke, elems, || {
                        let mut s = 0.0f32;
                        for q in &q_rows {
                            s += (ks.dot_i8)(q, 0.03125, &x);
                        }
                        std::hint::black_box(s);
                    }),
                    "axpy_f32" => ns_per_elem(&label, smoke, elems, || {
                        for i in 0..t_rows {
                            (ks.axpy_f32)(0.5, rows.row(i), &mut acc);
                        }
                        std::hint::black_box(&mut acc);
                    }),
                    "online_attn" => ns_per_elem(&label, smoke, 2 * elems, || {
                        online_attn_into_with(ks, &x, &fpool, &fk, &fv, 0.125, &mut acc);
                        std::hint::black_box(&mut acc);
                    }),
                    "paged_gemm_tile" => ns_per_elem(&label, smoke, 8 * elems, || {
                        matmul_nt_paged_with(ks, &qtile, &ipool, &ik, &mut tile_out);
                        std::hint::black_box(&mut tile_out);
                    }),
                    _ => unreachable!(),
                };
                per_tier.push((ks.isa.to_string(), ns));
            }
            let scalar_ns = per_tier[0].1;
            let simd_ns = per_tier.get(1).map(|p| p.1);
            let speedup = simd_ns.map(|s| scalar_ns / s);
            if kernel == "dequant_dot_i8" {
                if let Some(sp) = speedup {
                    best_dequant_speedup = best_dequant_speedup.max(sp);
                }
            }
            report.row(&[
                format!("kernel_{kernel}_r{r}"),
                "speedup (scalar/simd)".into(),
                speedup.map(|s| fnum(s, 2)).unwrap_or_else(|| "n/a".into()),
            ]);
            let mut cell = Json::obj().set("scalar_ns_per_elem", scalar_ns);
            if let Some(s) = simd_ns {
                cell = cell.set("simd_ns_per_elem", s);
            }
            if let Some(s) = speedup {
                cell = cell.set("speedup", s);
            }
            width_json = width_json.set(kernel, cell);
        }
        results = results.set(&format!("r{r}"), width_json);
    }
    (results, best_dequant_speedup)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("KQSVD_BENCH_SMOKE")
        .map(|v| v == "1")
        .unwrap_or(false);
    let mut report = Table::new(&["benchmark", "metric", "value"]);

    // --- L3 substrate: matmul --------------------------------------------
    println!("matmul:");
    let matmul_sizes: &[usize] = if smoke { &[128] } else { &[128, 256, 512] };
    for &n in matmul_sizes {
        let mut rng = Pcg64::new(n as u64, 1);
        let a = Mat::randn(n, n, 1.0, &mut rng);
        let b = Mat::randn(n, n, 1.0, &mut rng);
        let m = bench(&format!("matmul {n}x{n}x{n}"), 2, 10, || {
            std::hint::black_box(a.matmul(&b));
        });
        let gflops = 2.0 * (n as f64).powi(3) / m.min_s / 1e9;
        report.row(&[format!("matmul_{n}"), "GFLOP/s".into(), fnum(gflops, 2)]);
    }

    // --- SVD (calibration kernel) ----------------------------------------
    println!("\nSVD (QR + one-sided Jacobi, f64):");
    let svd_shapes: &[(usize, usize)] =
        if smoke { &[(1024, 32)] } else { &[(4096, 32), (4096, 64), (16384, 64)] };
    for &(t, d) in svd_shapes {
        let mut rng = Pcg64::new((t + d) as u64, 2);
        let a = Mat::randn(t, d, 1.0, &mut rng);
        let m = bench(&format!("svd {t}x{d}"), 1, 3, || {
            std::hint::black_box(Svd::compute(&a));
        });
        report.row(&[format!("svd_{t}x{d}"), "ms".into(), fnum(m.mean_s * 1e3, 1)]);
    }

    // --- compressed attention kernel (Rust twin of the Pallas L1) ---------
    println!("\nonline-softmax compressed attention (per query):");
    let attn_shapes: &[(usize, usize)] =
        if smoke { &[(512, 16)] } else { &[(512, 16), (2048, 16), (2048, 32)] };
    for &(t, r) in attn_shapes {
        let mut rng = Pcg64::new((t * r) as u64, 3);
        let ck_m = Mat::randn(t, r, 1.0, &mut rng);
        let cv_m = Mat::randn(t, r, 1.0, &mut rng);
        let mut pool = PagePool::new(16);
        let mut ck = BlockTable::new(r);
        let mut cv = BlockTable::new(r);
        for i in 0..t {
            pool.push_row(&mut ck, ck_m.row(i));
            pool.push_row(&mut cv, cv_m.row(i));
        }
        let q: Vec<f32> = (0..r).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let m = bench(&format!("online_attn T={t} R={r}"), 10, 50, || {
            std::hint::black_box(online_attn(&q, &pool, &ck, &cv, 0.125));
        });
        // Bytes streamed per call: T·(R+R)·4.
        let gbs = (t * r * 2 * 4) as f64 / m.min_s / 1e9;
        report.row(&[
            format!("online_attn_T{t}_R{r}"),
            "GB/s streamed".into(),
            fnum(gbs, 2),
        ]);
    }

    // --- per-kernel scalar-vs-SIMD A/B -------------------------------------
    let (kernel_json, dequant_speedup) = kernel_ab_section(&mut report, smoke);
    std::fs::write("BENCH_kernels.json", kernel_json.to_string_pretty())?;
    println!("kernel A/B JSON → BENCH_kernels.json");

    // --- engine decode step ------------------------------------------------
    println!("\nengine decode step (mha-small, rust backend):");
    let mut cfg = Config::from_preset("mha-small").map_err(anyhow::Error::msg)?;
    cfg.method = Method::KqSvd;
    cfg.calib.n_calib_seqs = if smoke { 2 } else { 8 };
    cfg.calib.calib_seq_len = if smoke { 64 } else { 256 };
    cfg.run_dir = "runs/bench_micro".into();
    let mut engine = build_engine(&cfg)?;
    engine.alloc(1, 640).unwrap();
    // Prefill 128 tokens of context.
    let prompt: Vec<u32> = (0..128).map(|i| (i % 60 + 1) as u32).collect();
    engine.prefill(1, &prompt, 0, true)?;
    let mut step = 0u32;
    let m = bench("decode_step ctx≈128", 3, if smoke { 5 } else { 30 }, || {
        step = (step + 1) % 60;
        std::hint::black_box(engine.decode(&[(1, step + 1)]).unwrap());
    });
    report.row(&["decode_step_ctx128".into(), "ms".into(), fnum(m.mean_s * 1e3, 3)]);
    report.row(&[
        "decode_step_ctx128".into(),
        "tok/s (batch 1)".into(),
        fnum(1.0 / m.mean_s, 1),
    ]);

    // --- scheduler overhead (mock engine, no model math) -------------------
    println!("\nscheduler overhead:");
    {
        use kqsvd::coordinator::{BatcherConfig, Request, Router};
        let m = bench("router 64 reqs (mock-free math via tiny model)", 1, 3, || {
            let mut cfg = Config::from_preset("test-tiny").unwrap();
            cfg.method = Method::KqSvd;
            cfg.calib.n_calib_seqs = 2;
            cfg.calib.calib_seq_len = 32;
            cfg.run_dir = "runs/bench_micro_tiny".into();
            let mut eng = build_engine(&cfg).unwrap();
            let mut router = Router::new(BatcherConfig {
                max_batch: 8,
                max_queue: 128,
                prefill_chunk: 16,
                ..Default::default()
            });
            for i in 0..64 {
                router
                    .submit(&eng, Request::new(i, vec![1, 2, 3, 4], 4))
                    .unwrap();
            }
            std::hint::black_box(router.run_offline(&mut eng).unwrap());
        });
        report.row(&["router_64req_tiny".into(), "ms".into(), fnum(m.mean_s * 1e3, 1)]);
    }

    println!("\nsummary:");
    report.print();
    report.write_csv("microbench.csv")?;
    println!("CSV → bench_out/microbench.csv");

    // Acceptance gate (ISSUE 7): with a SIMD tier active and a full (non-
    // smoke) run, the fused dequant-dot must beat scalar by ≥2× at some
    // width. Smoke runs skip the assert (iters too few to be stable).
    if !smoke && simd_table().is_some() {
        anyhow::ensure!(
            dequant_speedup >= 2.0,
            "dequant-dot SIMD speedup {dequant_speedup:.2}× below the 2× acceptance floor \
             (see BENCH_kernels.json)"
        );
        println!("dequant-dot acceptance: {dequant_speedup:.2}× ≥ 2× ✓");
    }
    Ok(())
}
