//! CALIB-COST — verifies the §4.3 complexity claim: computing the KQ-SVD
//! closed form costs O(Td²) — linear in the aggregated cache length T at
//! fixed d, quadratic-ish in d at fixed T — and stays within a small factor
//! of plain K-SVD (same asymptotics, two extra thin SVDs).
//!
//! Run: `cargo bench --bench calib_cost`

use kqsvd::bench_support::{bench, f as fnum, Table};
use kqsvd::compress::{eigen_key, kqsvd_key, ksvd_key};
use kqsvd::linalg::Mat;
use kqsvd::util::rng::Pcg64;

fn main() {
    println!("CALIB-COST: projection computation scaling (paper §4.3: O(Td²))\n");

    // T sweep at fixed d.
    let d = 64;
    let r = 16;
    println!("T sweep (d = {d}):");
    let mut t_table = Table::new(&["T", "ksvd(s)", "eigen(s)", "kqsvd(s)", "kqsvd T-ratio"]);
    let mut prev: Option<(usize, f64)> = None;
    let mut linearish = true;
    for t in [2048usize, 4096, 8192, 16384] {
        let mut rng = Pcg64::new(t as u64, 3);
        let k = Mat::randn(t, d, 1.0, &mut rng);
        let q = Mat::randn(t, d, 1.0, &mut rng);
        let m_ks = bench(&format!("ksvd  T={t}"), 1, 3, || {
            std::hint::black_box(ksvd_key(&k, r));
        });
        let m_ei = bench(&format!("eigen T={t}"), 1, 3, || {
            std::hint::black_box(eigen_key(&k, &q, r));
        });
        let m_kq = bench(&format!("kqsvd T={t}"), 1, 3, || {
            std::hint::black_box(kqsvd_key(&k, &q, r));
        });
        let ratio = prev
            .map(|(pt, ps)| (m_kq.mean_s / ps) / (t as f64 / pt as f64))
            .unwrap_or(1.0);
        // Linear scaling ⇒ time ratio ≈ T ratio ⇒ normalized ratio ≈ 1.
        if prev.is_some() && !(0.4..2.5).contains(&ratio) {
            linearish = false;
        }
        prev = Some((t, m_kq.mean_s));
        t_table.row(&[
            t.to_string(),
            fnum(m_ks.mean_s, 4),
            fnum(m_ei.mean_s, 4),
            fnum(m_kq.mean_s, 4),
            fnum(ratio, 2),
        ]);
    }
    t_table.print();
    t_table.write_csv("calib_cost_T.csv").unwrap();
    println!(
        "T-scaling ≈ linear: {}\n",
        if linearish { "HOLDS" } else { "VIOLATED" }
    );

    // d sweep at fixed T.
    let t = 8192;
    println!("d sweep (T = {t}):");
    let mut d_table = Table::new(&["d", "kqsvd(s)"]);
    for d in [16usize, 32, 64, 128] {
        let mut rng = Pcg64::new(d as u64, 5);
        let k = Mat::randn(t, d, 1.0, &mut rng);
        let q = Mat::randn(t, d, 1.0, &mut rng);
        let m = bench(&format!("kqsvd d={d}"), 1, 3, || {
            std::hint::black_box(kqsvd_key(&k, &q, (d / 4).max(2)));
        });
        d_table.row(&[d.to_string(), fnum(m.mean_s, 4)]);
    }
    d_table.print();
    d_table.write_csv("calib_cost_d.csv").unwrap();
    assert!(linearish, "T-scaling should be ~linear (O(Td²))");
    println!("\nCSV → bench_out/calib_cost_T.csv, bench_out/calib_cost_d.csv");
}
