//! TAB-RANK — the rank-selection table implied by §3.3/§6.1: per-layer
//! selected rank and cache memory ratio as the spectral-energy tolerance ε
//! varies, plus a numerical audit of the Theorem-3 identity on the real
//! calibration caches.
//!
//! Run: `cargo bench --bench tab_rank_memory`

use kqsvd::bench_support::{f as fnum, sci, Table};
use kqsvd::calib::{build_projections, collect_caches, select_ranks};
use kqsvd::compress::theorem3_gap;
use kqsvd::config::{CalibConfig, Method};
use kqsvd::eval::model_for;
use kqsvd::linalg::Mat;
use kqsvd::text::Corpus;

fn main() {
    let model = model_for("mha-small");
    let corpus = Corpus::new(model.cfg.vocab_size, 0);
    let base = CalibConfig {
        n_calib_seqs: 8,
        calib_seq_len: 256,
        ..CalibConfig::default()
    };
    println!("TAB-RANK on {} ({} calib × {})\n", model.cfg.name, base.n_calib_seqs, base.calib_seq_len);
    let caches = collect_caches(&model, &corpus, &base);

    // ε sweep → ranks and memory ratio.
    let mut t = Table::new(&["epsilon", "key ranks per layer", "value ranks", "cache ratio"]);
    let mut prev_ratio = 0.0f64;
    for eps in [0.2, 0.1, 0.05, 0.01] {
        let calib = CalibConfig { epsilon: eps, value_epsilon: eps, ..base.clone() };
        let ranks = select_ranks(&caches, &calib);
        let wo: Vec<Mat> = model.weights.layers.iter().map(|l| l.wo.clone()).collect();
        let set = build_projections(&model.cfg, &wo, &caches, &ranks, Method::KqSvd);
        let ratio = set.compression_ratio(&model.cfg);
        t.row(&[
            format!("{eps}"),
            format!("{:?}", ranks.iter().map(|r| r.r_key).collect::<Vec<_>>()),
            format!("{:?}", ranks.iter().map(|r| r.r_value).collect::<Vec<_>>()),
            fnum(ratio, 4),
        ]);
        // Tighter tolerance keeps more rank → cache ratio must not shrink.
        assert!(ratio >= prev_ratio - 1e-12, "smaller ε must not shrink the cache");
        prev_ratio = ratio;
    }
    t.print();
    t.write_csv("tab_rank_memory.csv").unwrap();

    // THM3 audit on real caches: identity residual + non-negativity, every
    // layer, first KV head, rank from ε = 0.1.
    println!("\nTheorem-3 identity audit (per layer, ε = 0.1 rank):");
    let ranks = select_ranks(&caches, &base);
    let mut audit = Table::new(&["layer", "R", "err_ksvd", "opt", "gap", "residual"]);
    for (li, lc) in caches.layers.iter().enumerate() {
        let g = theorem3_gap(&lc.k[0], &lc.q[0], ranks[li].r_key);
        assert!(g.identity_residual() < 1e-3, "layer {li}: residual {}", g.identity_residual());
        assert!(g.gap_lhs() >= -1e-4 * (g.top_energy + g.opt), "layer {li}: negative gap");
        audit.row(&[
            li.to_string(),
            ranks[li].r_key.to_string(),
            sci(g.err_ksvd),
            sci(g.opt),
            sci(g.gap_lhs()),
            sci(g.identity_residual()),
        ]);
    }
    audit.print();
    audit.write_csv("thm3_audit.csv").unwrap();
    println!("\nidentity holds on every layer; gap ≥ 0 (K-SVD never beats KQ-SVD).");
    println!("CSV → bench_out/tab_rank_memory.csv, bench_out/thm3_audit.csv");
}
