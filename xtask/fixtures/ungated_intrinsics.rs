// lint-as: rust/src/linalg/fixture.rs
// expect-lint: simd-gating
//
// Negative fixture: a bare `core::arch` import with no
// `#[cfg(feature = "simd")]` gate. A scalar-only build
// (`--no-default-features`, the Miri lane) would compile the intrinsics
// anyway, defeating the tier split. This file is lint fodder, never
// compiled.

use core::arch::x86_64::*;

pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    // Body irrelevant — the import line above is the violation.
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}
