// lint-as: rust/src/util/flag_ok.rs
// expect-lint: none
//
// Positive control for `atomic-ordering`: the flag pair uses
// Release/Acquire, and the only Relaxed site is an annotated monotonic
// counter (the suppression is counted, not silent).

struct Shutdown {
    stop: AtomicBool,
    laps: AtomicU64,
}

impl Shutdown {
    fn request(&self) {
        self.stop.store(true, Ordering::Release);
    }

    fn should_stop(&self) -> bool {
        // lint-ok(atomic-ordering): monotonic lap counter — readers only ever sum it, ordering never matters
        self.laps.fetch_add(1, Ordering::Relaxed);
        self.stop.load(Ordering::Acquire)
    }
}
