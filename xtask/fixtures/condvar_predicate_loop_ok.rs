// lint-as: rust/src/util/cv_wait_ok.rs
// expect-lint: none
//
// Positive control for `condvar-discipline`: the wait rebinds its guard
// from the wait result inside a `while` that re-checks the predicate
// under the lock, and the mutator notifies the paired condvar.

struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn wait_open(&self) {
        let mut g = self.open.lock().unwrap();
        while !*g {
            g = self.cv.wait(g).unwrap();
        }
    }

    fn open_up(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }
}
