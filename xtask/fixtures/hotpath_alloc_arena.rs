// lint-as: rust/src/coordinator/batcher.rs
// expect-lint: none
//
// Near-miss control for hot-path-alloc: the same reachable-from-step shape
// as hotpath_alloc.rs, but the allocation lives in a `*Scratch` type (the
// sanctioned grow-only arena), resolved through field-type inference on
// `self.scratch`. Must produce zero findings.

struct Batcher {
    scratch: DecodeScratch,
    max_batch: usize,
}

struct DecodeScratch {
    slots: Vec<usize>,
}

impl Batcher {
    fn step(&mut self) -> usize {
        self.scratch.ensure(self.max_batch);
        self.max_batch
    }
}

impl DecodeScratch {
    fn ensure(&mut self, max_batch: usize) {
        if self.slots.capacity() < max_batch {
            self.slots = Vec::with_capacity(max_batch);
        }
    }
}
