// lint-as: rust/src/attn/parallel_ok.rs
// expect-lint: none
//
// Near-miss control for sendptr-escape: the SendPtr sits in a fn that
// derives disjoint ranges via `split_at_mut`, and the aux section below
// stands in for rust/tests/miri_kernels.rs with a test naming the fn.
// Must produce zero findings.

fn scatter_rows(out: &mut [f32], mid: usize) {
    let (lo, hi) = out.split_at_mut(mid);
    let base = SendPtr(lo.as_mut_ptr());
    spawn_workers(base, hi.len());
}

//=== file: rust/tests/miri_kernels.rs
#[test]
fn miri_scatter_rows_disjoint() {
    let mut out = [0.0f32; 8];
    scatter_rows(&mut out, 4);
}
