// lint-as: rust/src/coordinator/fleet.rs
// expect-lint: hot-path-alloc
//
// Negative fixture: `FleetDispatch::route_request` — the per-submission
// fleet routing hot root — reaches an allocating helper one hop down (a
// fingerprint buffer rebuilt per routed request). The real implementation
// must scan the prompt with plain loops and read caller-built load
// snapshots; any allocation on this path must fire the whole-program lint.
// This file is lint fodder, never compiled.

impl FleetDispatch {
    fn route_request(&self, prompt: &[u32], loads: &[LoadSnapshot]) -> usize {
        let chains = chunk_chains(prompt, self.chunk_tokens);
        chains.len() % loads.len().max(1)
    }
}

fn chunk_chains(prompt: &[u32], chunk_tokens: usize) -> Vec<u64> {
    let mut chains = Vec::with_capacity(prompt.len() / chunk_tokens.max(1));
    chains.push(prompt.len() as u64);
    chains
}
