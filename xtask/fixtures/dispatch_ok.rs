// lint-as: rust/src/linalg/fixture_dispatch_ok.rs
// expect-lint: none
//
// Near-miss control for dispatch-parity-drift: the same fn-pointer field
// as dispatch_drift.rs, but with all four artifacts present — a scalar
// arm, a feature-gated SIMD arm, a parity test (aux section below), and a
// DESIGN §5e table row (aux section below). Must produce zero findings.

pub struct KernelDispatch {
    pub gemv_f32: fn(&[f32], &[f32], &mut [f32]),
}

mod scalar {
    pub fn gemv_f32(a: &[f32], x: &[f32], y: &mut [f32]) {
        for (row, out) in y.iter_mut().enumerate() {
            *out = dot_row(a, x, row);
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    pub fn gemv_f32(a: &[f32], x: &[f32], y: &mut [f32]) {
        super::scalar::gemv_f32(a, x, y);
    }
}

//=== file: rust/tests/kernel_parity_test.rs
#[test]
fn gemv_f32_parity_scalar_vs_simd() {
    assert_parity(gemv_f32);
}

//=== file: DESIGN.md
## §5 kernels

### §5e parity table

| kernel | oracle |
| --- | --- |
| `gemv_f32` scalar vs simd | bitwise |
