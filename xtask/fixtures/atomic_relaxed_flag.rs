// lint-as: rust/src/util/flag.rs
// expect-lint: atomic-ordering
//
// Negative fixture: an `AtomicBool` flag pair published with Relaxed on
// both sides — the flag can outrun the payload it advertises — plus an
// unannotated Relaxed counter bump. The field table resolves `stop` to
// `Shutdown.stop`, so the flag-pair discipline applies.

struct Shutdown {
    stop: AtomicBool,
    laps: AtomicU64,
}

impl Shutdown {
    fn request(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    fn should_stop(&self) -> bool {
        self.laps.fetch_add(1, Ordering::Relaxed);
        self.stop.load(Ordering::Relaxed)
    }
}
