// lint-as: rust/src/util/cv_wait.rs
// expect-lint: condvar-discipline
//
// Negative fixture: a bare `Condvar::wait` with no predicate loop — a
// spurious wakeup proceeds on a false predicate — plus a guarded-state
// mutation in a fn that never notifies the paired condvar, so a waiter
// can sleep through the very update it is waiting for.

struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn wait_open(&self) {
        let g = self.open.lock().unwrap();
        let g = self.cv.wait(g).unwrap();
        drop(g);
    }

    fn open_up(&self) {
        *self.open.lock().unwrap() = true;
    }
}
