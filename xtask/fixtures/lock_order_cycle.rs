// lint-as: rust/src/util/ab_locks.rs
// expect-lint: lock-order
//
// Negative fixture: two mutexes taken in opposite nesting orders on two
// paths — a classic ABBA deadlock. The acquisition-order graph must see
// the `Pair.a` → `Pair.b` edge from `forward` and the `Pair.b` → `Pair.a`
// edge from `backward` and flag the cycle. This file is lint fodder,
// never compiled.

struct Pair {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

impl Pair {
    fn forward(&self) {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        drop(gb);
        drop(ga);
    }

    fn backward(&self) {
        let gb = self.b.lock().unwrap();
        let ga = self.a.lock().unwrap();
        drop(ga);
        drop(gb);
    }
}
