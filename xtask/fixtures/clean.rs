// lint-as: rust/src/kvcache/fixture.rs
// expect-lint: none
//
// Clean control fixture: exercises the allowed form of everything the
// other fixtures get flagged for — accessor calls instead of raw fields,
// u64-native math plus an annotated narrowing, a documented unsafe block,
// and hot-path error flow via Result. Must produce zero findings.

pub fn admit_budget(pool: &PagePool, need: u64) -> bool {
    pool.used_bytes() + need <= pool.budget_bytes()
}

pub fn rows_in(total_bytes: u64, row_bytes: u64) -> usize {
    (total_bytes / row_bytes) as usize // cast-ok: bounded by pool capacity < 2^32
}

pub fn read_first(data: &[u8]) -> Option<u8> {
    if data.is_empty() {
        return None;
    }
    let p = data.as_ptr();
    // SAFETY: `data` is non-empty (checked above), so `p` points to its
    // first initialized byte; the read does not outlive the borrow.
    Some(unsafe { *p })
}

impl Batcher {
    fn admit_one(&mut self) -> anyhow::Result<()> {
        let st = self
            .queue
            .pop_front()
            .ok_or_else(|| anyhow::anyhow!("empty queue"))?;
        self.running.push(st);
        Ok(())
    }
}
