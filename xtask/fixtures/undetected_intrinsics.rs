// lint-as: rust/src/linalg/fixture.rs
// expect-lint: simd-gating
//
// Negative fixture: the intrinsics are correctly feature-gated, but the
// file has no runtime `is_x86_feature_detected!` check anywhere — so a
// `simd`-feature build would execute AVX2 code on hosts without AVX2.
// Compiling an ISA arm must never imply executing it. This file is lint
// fodder, never compiled.

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use core::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    pub unsafe fn sum8(p: *const f32) -> f32 {
        // SAFETY: caller guarantees p points at 8 readable f32s.
        let v = unsafe { _mm256_loadu_ps(p) };
        let mut out = [0.0f32; 8];
        // SAFETY: out is exactly 8 f32s, properly aligned for storeu.
        unsafe { _mm256_storeu_ps(out.as_mut_ptr(), v) };
        out.iter().sum()
    }
}
