// lint-as: rust/src/util/pump_ok.rs
// expect-lint: none
//
// Positive control for `channel-lifecycle`: the pump thread's handle is
// bound and joined after the sender side is dropped, and the receive
// loop exits on disconnect instead of unwrapping.

fn run_pump(tx: Sender<u32>, rx: Receiver<u32>) {
    let pump = std::thread::spawn(move || loop {
        match rx.recv() {
            Ok(_) => {}
            Err(_) => break,
        }
    });
    drop(tx);
    pump.join().unwrap();
}
