// lint-as: rust/src/kvcache/fixture_units_ok.rs
// expect-lint: none
//
// Near-miss control for unit-confusion: the same byte/token mix as
// unit_confusion.rs, but routed through the blessed converter and a
// `_per_` ratio factor — both of which change the unit legitimately.
// Must produce zero findings.

pub fn admission_headroom(cfg: &ModelConfig, pool_budget_bytes: u64, prompt_tokens: u64) -> u64 {
    let need_bytes = cfg.bytes_for_tokens(prompt_tokens);
    pool_budget_bytes - need_bytes
}

pub fn projected_use(bytes_per_token: u64, prompt_tokens: u64, pool_budget_bytes: u64) -> bool {
    let projected = bytes_per_token * prompt_tokens;
    projected <= pool_budget_bytes
}
