// lint-as: rust/src/util/ab_locks_ok.rs
// expect-lint: none
//
// Positive control for `lock-order`: the same two mutexes are always
// nested in the same order — directly in `forward`, and across a call
// edge in `forward_via_helper` (the callee's transitive lock set adds
// the identical `Pair.a` → `Pair.b` edge). Acyclic graph, no finding.

struct Pair {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

impl Pair {
    fn forward(&self) {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        drop(gb);
        drop(ga);
    }

    fn forward_via_helper(&self) {
        let ga = self.a.lock().unwrap();
        self.tail();
        drop(ga);
    }

    fn tail(&self) {
        let gb = self.b.lock().unwrap();
        drop(gb);
    }
}
