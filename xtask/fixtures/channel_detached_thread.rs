// lint-as: rust/src/util/pump.rs
// expect-lint: channel-lifecycle
//
// Negative fixture: a pump thread is spawned and its JoinHandle dropped
// on the floor — with a `Sender` moved inside, teardown can leave the
// receiver blocked forever — and the receive loop unwraps, so a dropped
// sender becomes a panic instead of a clean exit.

fn start_pump(tx: Sender<u32>, rx: Receiver<u32>) {
    std::thread::spawn(move || {
        let mut last = 0;
        loop {
            last = rx.recv().unwrap();
        }
    });
    drop(tx);
}
