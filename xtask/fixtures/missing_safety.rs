// lint-as: rust/src/util/fixture.rs
// expect-lint: safety-comments
//
// Negative fixture: an unsafe block with no preceding safety comment.
// This file is lint fodder, never compiled.

pub fn read_raw(p: *const u8) -> u8 {
    unsafe { *p }
}
