// lint-as: rust/src/coordinator/batcher.rs
// expect-lint: hot-path-panics
//
// Negative fixture: an unwrap on the scheduler hot path. A poisoned queue
// entry here would abort the whole serving loop instead of rejecting one
// request. This file is lint fodder, never compiled.

impl Batcher {
    fn admit_one(&mut self) {
        let st = self.queue.pop_front().unwrap();
        self.running.push(st);
    }
}
