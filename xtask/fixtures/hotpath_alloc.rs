// lint-as: rust/src/coordinator/batcher.rs
// expect-lint: hot-path-alloc
//
// Negative fixture: a helper two call-graph hops below `Batcher::step`
// allocates a fresh Vec every step. Line-oriented scanning cannot see
// this — only reachability can. This file is lint fodder, never compiled.

impl Batcher {
    fn step(&mut self) -> usize {
        self.plan_round()
    }

    fn plan_round(&mut self) -> usize {
        gather_slots(self.max_batch)
    }
}

fn gather_slots(max_batch: usize) -> usize {
    let mut slots = Vec::with_capacity(max_batch);
    slots.push(0usize);
    slots.len()
}
