// lint-as: rust/src/linalg/fixture_dispatch.rs
// expect-lint: dispatch-parity-drift
//
// Negative fixture: a `KernelDispatch` fn-pointer field with no scalar
// arm, no gated SIMD arm, no parity test, and no DESIGN §5e row — the
// four ways a new kernel silently dodges the parity harness. This file is
// lint fodder, never compiled.

pub struct KernelDispatch {
    pub gemv_f32: fn(&[f32], &[f32], &mut [f32]),
}
