// lint-as: rust/src/kvcache/fixture_units.rs
// expect-lint: unit-confusion
//
// Negative fixture: adding a byte count to a token count compiles fine
// (both u64) and is always a bug. The unit flows through a let-binding
// before the bad add, so suffix-only line scanning would miss it. This
// file is lint fodder, never compiled.

pub fn admission_headroom(pool_budget_bytes: u64, prompt_tokens: u64) -> u64 {
    let budget = pool_budget_bytes;
    budget + prompt_tokens
}
