// lint-as: rust/src/server/fixture.rs
// expect-lint: accounting-fields
//
// Negative fixture: mutating a pool accounting counter directly from
// outside kvcache, bypassing the incremental-counter API that
// `verify_accounting` audits. This file is lint fodder, never compiled.

pub fn leak_pages(pool: &mut PagePool, page_bytes: u64) {
    pool.used_bytes += page_bytes;
    pool.cold_bytes = 0;
}
