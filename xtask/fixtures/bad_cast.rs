// lint-as: rust/src/kvcache/fixture.rs
// expect-lint: lossy-casts
//
// Negative fixture: a u64 byte count truncated to usize in an accounting
// path without justification. `cargo xtask fixtures` verifies the
// `lossy-casts` rule flags it. This file is lint fodder, never compiled.

pub fn bytes_to_len(total_bytes: u64, row_bytes: u64) -> usize {
    (total_bytes / row_bytes) as usize
}
