// lint-as: rust/src/attn/parallel.rs
// expect-lint: sendptr-escape
//
// Negative fixture: a `SendPtr` minted in a function that derives no
// disjoint ranges (no parallel_for / chunks / split_at idiom) and that no
// miri_kernels.rs test names. Both halves of the SendPtr contract are
// broken. This file is lint fodder, never compiled.

fn scatter_rows(out: &mut [f32], stride: usize) {
    let base = SendPtr(out.as_mut_ptr());
    spawn_workers(base, stride);
}
