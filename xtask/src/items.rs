//! Item tree: one walker pass over a file's token stream collecting fns
//! (with their impl/trait context and module path), structs (with field
//! names and first type token), and the set of trait-declared method names
//! (used for dynamic-dispatch over-approximation in the call graph).
//!
//! Fn bodies are consumed whole: nested item definitions inside a body are
//! attributed to the enclosing fn — correct for reachability, since a
//! nested fn is only callable from its parent.
//!
//! Keep in lockstep with the `parse_items` section of
//! `tools/lint_mirror.py`.

use std::collections::HashSet;

use crate::lexer::{
    match_brace_toks, match_bracket_toks, match_paren_toks, skip_angle, tok_is_ident, Tok,
};
use crate::scan::Scanned;

/// One `fn` definition (declarations without a body are recorded only in
/// `trait_methods`).
#[derive(Debug, Clone)]
pub struct FnItem {
    pub name: String,
    /// Innermost enclosing impl/trait self-type name (`impl Foo` → `Foo`,
    /// `impl Trait for Foo` → `Foo`); `None` for free fns.
    pub ctx: Option<String>,
    /// Module path: file-level segments (filled in by the crate model)
    /// followed by inline `mod` names.
    pub mods: Vec<String>,
    pub sig_line: usize,
    /// Body token range, exclusive of the braces.
    pub body: (usize, usize),
    pub end_line: usize,
    pub is_test: bool,
    pub is_simd: bool,
}

/// One `struct` definition with named fields.
#[derive(Debug, Clone)]
pub struct StructItem {
    pub name: String,
    pub line: usize,
    /// (field name, line, first token of the field type) — the first type
    /// token is enough to recognize `fn`-pointer fields and crate types.
    pub fields: Vec<(String, usize, String)>,
    pub is_test: bool,
}

fn line_flag(flags: &[bool], ln: usize) -> bool {
    ln >= 1 && flags.get(ln - 1).copied().unwrap_or(false)
}

pub fn parse_items(
    toks: &[Tok],
    scanned: &Scanned,
) -> (Vec<FnItem>, Vec<StructItem>, HashSet<String>) {
    let mut fns = Vec::new();
    let mut structs = Vec::new();
    let mut trait_methods: HashSet<String> = HashSet::new();
    // ("impl" | "trait" | "mod" | "block", name)
    let mut scopes: Vec<(&'static str, Option<String>)> = Vec::new();
    let n = toks.len();
    let mut i = 0usize;

    while i < n {
        let t = toks[i].text.as_str();
        let ln = toks[i].line;
        match t {
            "{" => {
                scopes.push(("block", None));
                i += 1;
            }
            "}" => {
                scopes.pop();
                i += 1;
            }
            "impl" | "trait" => {
                let is_trait = t == "trait";
                let mut j = i + 1;
                let mut name: Option<String> = None;
                if is_trait {
                    // `trait Name` — supertrait bounds may follow; name first.
                    if j < n && tok_is_ident(&toks[j].text) {
                        name = Some(toks[j].text.clone());
                    }
                    while j < n && toks[j].text != "{" && toks[j].text != ";" {
                        if toks[j].text == "<" {
                            j = skip_angle(toks, j);
                        } else {
                            j += 1;
                        }
                    }
                } else {
                    if j < n && toks[j].text == "<" {
                        j = skip_angle(toks, j);
                    }
                    // The self type is the *last* ident before the body:
                    // `impl Trait for Foo` resets at `for` and ends on `Foo`.
                    while j < n && toks[j].text != "{" && toks[j].text != ";" {
                        let tj = toks[j].text.as_str();
                        if tj == "<" {
                            j = skip_angle(toks, j);
                        } else if tj == "for" {
                            name = None;
                            j += 1;
                        } else if tok_is_ident(tj) {
                            name = Some(tj.to_string());
                            j += 1;
                        } else {
                            j += 1;
                        }
                    }
                }
                if j < n && toks[j].text == "{" {
                    scopes.push((if is_trait { "trait" } else { "impl" }, name));
                }
                i = j + 1;
            }
            "mod" if i + 1 < n && tok_is_ident(&toks[i + 1].text) => {
                if i + 2 < n && toks[i + 2].text == "{" {
                    scopes.push(("mod", Some(toks[i + 1].text.clone())));
                    i += 3;
                } else {
                    i += 2;
                }
            }
            "struct" if i + 1 < n && tok_is_ident(&toks[i + 1].text) => {
                let sname = toks[i + 1].text.clone();
                let sline = toks[i + 1].line;
                let mut j = i + 2;
                if j < n && toks[j].text == "<" {
                    j = skip_angle(toks, j);
                }
                if j < n && toks[j].text == "{" {
                    let close = match_brace_toks(toks, j);
                    let mut fields = Vec::new();
                    let mut k = j + 1;
                    while k < close {
                        let tk = toks[k].text.as_str();
                        if tk == "(" || tk == "[" {
                            k = if tk == "(" {
                                match_paren_toks(toks, k)
                            } else {
                                match_bracket_toks(toks, k)
                            } + 1;
                            continue;
                        }
                        if tk == "{" {
                            k = match_brace_toks(toks, k) + 1;
                            continue;
                        }
                        // `name: Type` at field position: first field, or
                        // preceded by a separator / visibility keyword.
                        if tok_is_ident(tk)
                            && k + 1 < close
                            && toks[k + 1].text == ":"
                            && (k == j + 1
                                || matches!(toks[k - 1].text.as_str(), "," | "{" | ")" | "pub"))
                        {
                            let first_ty = if k + 2 < close {
                                toks[k + 2].text.clone()
                            } else {
                                String::new()
                            };
                            fields.push((tk.to_string(), toks[k].line, first_ty));
                            k += 2;
                            continue;
                        }
                        k += 1;
                    }
                    structs.push(StructItem {
                        name: sname,
                        line: sline,
                        fields,
                        is_test: line_flag(&scanned.test_lines, sline),
                    });
                    i = close + 1;
                } else {
                    // Tuple / unit struct: skip to `;`.
                    while j < n && toks[j].text != ";" {
                        j += 1;
                    }
                    i = j + 1;
                }
            }
            "fn" if i + 1 < n && tok_is_ident(&toks[i + 1].text) => {
                let name = toks[i + 1].text.clone();
                let mut j = i + 2;
                if j < n && toks[j].text == "<" {
                    j = skip_angle(toks, j);
                }
                while j < n && toks[j].text != "(" {
                    j += 1;
                }
                j = match_paren_toks(toks, j);
                let mut k = j + 1;
                while k < n && toks[k].text != "{" && toks[k].text != ";" {
                    k += 1;
                }
                if scopes.iter().any(|(kind, _)| *kind == "trait") {
                    trait_methods.insert(name.clone());
                }
                if k >= n || toks[k].text == ";" {
                    i = k + 1;
                    continue;
                }
                let close = match_brace_toks(toks, k);
                let ctx = scopes
                    .iter()
                    .rev()
                    .find(|(kind, _)| *kind == "impl" || *kind == "trait")
                    .and_then(|(_, nm)| nm.clone());
                let mods = scopes
                    .iter()
                    .filter(|(kind, _)| *kind == "mod")
                    .filter_map(|(_, nm)| nm.clone())
                    .collect();
                fns.push(FnItem {
                    name,
                    ctx,
                    mods,
                    sig_line: ln,
                    body: (k + 1, close),
                    end_line: toks[close].line,
                    is_test: line_flag(&scanned.test_lines, ln),
                    is_simd: line_flag(&scanned.simd_lines, ln),
                });
                i = close + 1;
            }
            _ => i += 1,
        }
    }
    (fns, structs, trait_methods)
}

/// Module path segments a file contributes: `rust/src/attn/mod.rs` →
/// `["attn"]`, `rust/src/coordinator/batcher.rs` →
/// `["coordinator", "batcher"]`. Fixture paths outside `rust/src` get
/// their bare stem.
pub fn file_mod_path(rel: &str) -> Vec<String> {
    let norm = rel.replace('\\', "/");
    let parts: Vec<&str> = norm.split('/').collect();
    let mut parts: Vec<String> = if parts.len() >= 2 && parts[0] == "rust" && parts[1] == "src" {
        parts[2..].iter().map(|s| s.to_string()).collect()
    } else {
        parts.last().map(|s| s.to_string()).into_iter().collect()
    };
    if let Some(last) = parts.last_mut() {
        if let Some(stem) = last.strip_suffix(".rs") {
            *last = stem.to_string();
        }
    }
    if matches!(parts.last().map(String::as_str), Some("mod") | Some("lib") | Some("main")) {
        parts.pop();
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;
    use crate::scan::scan;

    fn items(src: &str) -> (Vec<FnItem>, Vec<StructItem>, HashSet<String>) {
        let s = scan(src);
        let toks = lex(&s.masked);
        parse_items(&toks, &s)
    }

    #[test]
    fn fn_ctx_and_mods() {
        let src = "mod inner {\n  impl Foo {\n    fn bar(&self) { baz(); }\n  }\n}\nfn free() {}\n";
        let (fns, _, _) = items(src);
        assert_eq!(fns.len(), 2);
        assert_eq!(fns[0].name, "bar");
        assert_eq!(fns[0].ctx.as_deref(), Some("Foo"));
        assert_eq!(fns[0].mods, vec!["inner"]);
        assert_eq!(fns[1].name, "free");
        assert_eq!(fns[1].ctx, None);
    }

    #[test]
    fn impl_trait_for_type_takes_self_type() {
        let src = "impl<T: Clone> Display for Wrapper<T> {\n  fn fmt(&self) {}\n}\n";
        let (fns, _, _) = items(src);
        assert_eq!(fns[0].ctx.as_deref(), Some("Wrapper"));
    }

    #[test]
    fn trait_decls_collected_even_bodiless() {
        let src = "trait Engine {\n  fn alloc(&mut self);\n  fn free(&mut self) { dealloc(); }\n}\n";
        let (fns, _, traits) = items(src);
        assert!(traits.contains("alloc") && traits.contains("free"));
        // Only the defaulted method has a body item.
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].name, "free");
        assert_eq!(fns[0].ctx.as_deref(), Some("Engine"));
    }

    #[test]
    fn struct_fields_with_first_type_token() {
        let src = "struct Table {\n  pub pages: Vec<u32>,\n  hook: fn(usize) -> usize,\n  width: usize,\n}\n";
        let (_, structs, _) = items(src);
        let f = &structs[0].fields;
        assert_eq!(f.len(), 3);
        assert_eq!((f[0].0.as_str(), f[0].2.as_str()), ("pages", "Vec"));
        assert_eq!((f[1].0.as_str(), f[1].2.as_str()), ("hook", "fn"));
        assert_eq!((f[2].0.as_str(), f[2].2.as_str()), ("width", "usize"));
    }

    #[test]
    fn nested_fn_attributed_to_parent() {
        let src = "fn outer() {\n  fn inner() {}\n  inner();\n}\n";
        let (fns, _, _) = items(src);
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].name, "outer");
    }

    #[test]
    fn mod_paths() {
        assert_eq!(file_mod_path("rust/src/attn/mod.rs"), vec!["attn"]);
        assert_eq!(
            file_mod_path("rust/src/coordinator/batcher.rs"),
            vec!["coordinator", "batcher"]
        );
        assert!(file_mod_path("rust/src/lib.rs").is_empty());
        assert_eq!(file_mod_path("fixture_case.rs"), vec!["fixture_case"]);
    }
}
