//! Suffix-driven unit inference for the `unit-confusion` lint.
//!
//! A value's unit comes from its name: `_bytes` / `_tokens` / `_pages` /
//! `_rows` suffixes carry the four accounting units, `_per_`-named values
//! (`bytes_per_token`, …) are ratios, and the blessed converters return
//! their true unit regardless of spelling (`bytes_for_tokens` RETURNS
//! bytes). Units propagate through let-bindings and arithmetic by a small
//! recursive-descent scanner over the token stream:
//!
//! * `+` / `-` / comparisons between two *different* units conflict;
//! * `*` by a ratio converts (result unit-free); a mixed-unit product is
//!   dimensionally new (unit-free); `/` and `%` by a unitful divisor yield
//!   a ratio (unit-free);
//! * `as` casts preserve the operand's unit; indexing/calls recurse into
//!   the group so nested arguments and closure bodies are still scanned.
//!
//! This is dataflow-lite, not a type system: a binding's suffix wins over
//! its initializer (the name is the declared intent), and anything the
//! scanner cannot classify is unit-free — unknown values never conflict,
//! so imprecision fails silent rather than noisy.
//!
//! Keep in lockstep with the `UnitScanner` section of
//! `tools/lint_mirror.py`.

use std::collections::HashMap;

use crate::lexer::{match_bracket_toks, match_paren_toks, skip_angle, tok_is_ident, Tok};

pub type Unit = &'static str;

const UNIT_SUFFIXES: [(&str, Unit); 4] = [
    ("_bytes", "bytes"),
    ("_tokens", "tokens"),
    ("_pages", "pages"),
    ("_rows", "rows"),
];
pub const UNITS: [Unit; 4] = ["bytes", "tokens", "pages", "rows"];

/// Blessed converters: the value each returns carries its true unit even
/// when the name's suffix says otherwise.
const UNIT_CONVERTERS: [(&str, Unit); 5] = [
    ("bytes_for_tokens", "bytes"),
    ("token_bytes", "bytes"),
    ("cache_bytes_per_token", "ratio"),
    ("bytes_per_token", "ratio"),
    ("bytes_per_token_for", "ratio"),
];

fn is_unit(u: Option<Unit>) -> bool {
    matches!(u, Some(x) if UNITS.contains(&x))
}

pub fn suffix_unit(name: &str) -> Option<Unit> {
    if name.contains("_per_") {
        return Some("ratio");
    }
    for (suf, unit) in UNIT_SUFFIXES {
        if name.ends_with(suf) || name == &suf[1..] {
            return Some(unit);
        }
    }
    None
}

fn unit_for(name: &str, env: &HashMap<String, Option<Unit>>) -> Option<Unit> {
    for (conv, unit) in UNIT_CONVERTERS {
        if name == conv {
            return Some(unit);
        }
    }
    if let Some(u) = env.get(name) {
        return *u;
    }
    suffix_unit(name)
}

/// A cross-unit `+`/`-`/comparison: (line, left unit, operator, right unit).
pub struct UnitConflict {
    pub line: usize,
    pub left: Unit,
    pub op: String,
    pub right: Unit,
}

const ADD_OPS: [&str; 4] = ["+", "-", "+=", "-="];
const CMP_OPS: [&str; 6] = ["<", ">", "<=", ">=", "==", "!="];
const UNARY_PREFIX: [&str; 6] = ["&", "mut", "*", "-", "+", "!"];
const MUL_OPS: [&str; 3] = ["*", "/", "%"];

/// Forward expression scanner over a fn body's tokens. Flags `+`/`-` and
/// comparisons whose two terms carry different unit suffixes.
pub struct UnitScanner<'a> {
    toks: &'a [Tok],
    end: usize,
    env: HashMap<String, Option<Unit>>,
    pub conflicts: Vec<UnitConflict>,
}

impl<'a> UnitScanner<'a> {
    pub fn new(toks: &'a [Tok], end: usize) -> UnitScanner<'a> {
        UnitScanner {
            toks,
            end,
            env: HashMap::new(),
            conflicts: Vec::new(),
        }
    }

    fn tok(&self, i: usize) -> &str {
        if i < self.end {
            self.toks[i].text.as_str()
        } else {
            ""
        }
    }

    fn line(&self, i: usize) -> usize {
        if i < self.end {
            self.toks[i].line
        } else {
            0
        }
    }

    pub fn scan_region(&mut self, mut i: usize, end: usize) {
        let saved = self.end;
        self.end = end.min(saved);
        while i < self.end {
            if self.tok(i) == "let" {
                i = self.parse_let(i);
                continue;
            }
            let (_, j) = self.parse_expr(i);
            i = if j > i { j } else { i + 1 };
        }
        self.end = saved;
    }

    /// `let [mut] NAME [: ty] = expr` — bind NAME's unit in env.
    fn parse_let(&mut self, i: usize) -> usize {
        let mut j = i + 1;
        if self.tok(j) == "mut" {
            j += 1;
        }
        if !tok_is_ident(self.tok(j)) {
            return i + 1;
        }
        let name = self.tok(j).to_string();
        j += 1;
        // Scan to `=` (stop at `;`); skip angle groups in type annotations.
        while j < self.end && self.tok(j) != "=" && self.tok(j) != ";" {
            if self.tok(j) == "<" {
                j = skip_angle(self.toks, j);
            } else {
                j += 1;
            }
        }
        if self.tok(j) != "=" {
            self.env.insert(name.clone(), suffix_unit(&name));
            return j + 1;
        }
        let (unit, k) = self.parse_expr(j + 1);
        // The name's suffix is the declared intent; the initializer's unit
        // is the fallback.
        self.env.insert(name.clone(), suffix_unit(&name).or(unit));
        if k > j + 1 {
            k
        } else {
            j + 2
        }
    }

    fn parse_expr(&mut self, i: usize) -> (Option<Unit>, usize) {
        let (mut lu, mut i) = self.parse_term(i);
        loop {
            let op = self.tok(i).to_string();
            if ADD_OPS.contains(&op.as_str()) || CMP_OPS.contains(&op.as_str()) {
                let line = self.line(i);
                let (ru, j) = self.parse_term(i + 1);
                if j == i + 1 {
                    return (lu, i);
                }
                if is_unit(lu) && is_unit(ru) && lu != ru {
                    self.conflicts.push(UnitConflict {
                        line,
                        left: lu.unwrap(),
                        op: op.clone(),
                        right: ru.unwrap(),
                    });
                }
                lu = if CMP_OPS.contains(&op.as_str()) {
                    None
                } else {
                    lu.or(ru)
                };
                i = j;
            } else {
                return (lu, i);
            }
        }
    }

    fn parse_term(&mut self, i: usize) -> (Option<Unit>, usize) {
        let (mut u, mut i) = self.parse_factor(i);
        loop {
            let op = self.tok(i).to_string();
            if MUL_OPS.contains(&op.as_str()) {
                let (u2, j) = self.parse_factor(i + 1);
                if j == i + 1 {
                    return (u, i);
                }
                if op == "*" {
                    if u == Some("ratio") || u2 == Some("ratio") {
                        u = None; // ratio factor converts the unit
                    } else if u.is_some() && u2.is_some() {
                        u = None; // mixed-unit product: dimensionally new
                    } else if u2.is_some() {
                        u = u2;
                    }
                } else {
                    // `/` or `%`
                    if u2.is_some() {
                        u = None; // unitful divisor: result is a ratio
                    }
                }
                i = j;
            } else {
                return (u, i);
            }
        }
    }

    fn parse_factor(&mut self, mut i: usize) -> (Option<Unit>, usize) {
        while UNARY_PREFIX.contains(&self.tok(i)) {
            i += 1;
        }
        let t = self.tok(i);
        if t == "(" {
            let close = match_paren_toks(self.toks, i);
            let (inner, _) = self.parse_expr(i + 1);
            self.scan_rest_of_group(i + 1, close);
            return self.postfix(inner, close + 1, true);
        }
        if tok_is_ident(t) {
            return self.chain(i);
        }
        if t.as_bytes().first().is_some_and(|b| b.is_ascii_digit()) {
            return self.postfix(None, i + 1, false);
        }
        (None, i)
    }

    /// After taking the group's leading expr for a unit, still walk the
    /// remainder (later args, closure bodies) for nested conflicts.
    fn scan_rest_of_group(&mut self, start: usize, close: usize) {
        let saved = self.end;
        self.end = close;
        self.scan_region(start, close);
        self.end = saved;
    }

    fn chain(&mut self, i: usize) -> (Option<Unit>, usize) {
        let last = self.tok(i).to_string();
        self.postfix_chain(last, i + 1)
    }

    fn postfix_chain(&mut self, mut last: String, mut i: usize) -> (Option<Unit>, usize) {
        loop {
            let t = self.tok(i).to_string();
            if t == "::" && tok_is_ident(self.tok(i + 1)) {
                last = self.tok(i + 1).to_string();
                i += 2;
            } else if t == "::" && self.tok(i + 1) == "<" {
                i = skip_angle(self.toks, i + 1);
            } else if t == "." {
                let nxt = self.tok(i + 1).to_string();
                if tok_is_ident(&nxt) {
                    last = nxt;
                    i += 2;
                } else if nxt.as_bytes().first().is_some_and(|b| b.is_ascii_digit()) {
                    i += 2;
                } else {
                    break;
                }
            } else if t == "(" {
                let close = match_paren_toks(self.toks, i);
                self.scan_rest_of_group(i + 1, close);
                i = close + 1;
            } else if t == "[" {
                let close = match_bracket_toks(self.toks, i);
                self.scan_rest_of_group(i + 1, close);
                i = close + 1;
            } else if t == "?" {
                i += 1;
            } else if t == "as" {
                // Keep the operand's unit across `x as u64`.
                i += 1;
                while self.tok(i) == "&" || self.tok(i) == "mut" {
                    i += 1;
                }
                if tok_is_ident(self.tok(i)) {
                    i += 1;
                    while self.tok(i) == "::" && tok_is_ident(self.tok(i + 1)) {
                        i += 2;
                    }
                    if self.tok(i) == "<" {
                        i = skip_angle(self.toks, i);
                    }
                }
            } else {
                break;
            }
        }
        (unit_for(&last, &self.env), i)
    }

    /// Non-ident primaries only take `.0` / `?` / `as` postfix.
    fn postfix(&mut self, unit: Option<Unit>, mut i: usize, keep_unit: bool) -> (Option<Unit>, usize) {
        loop {
            let t = self.tok(i);
            if t == "."
                && self
                    .tok(i + 1)
                    .as_bytes()
                    .first()
                    .is_some_and(|b| b.is_ascii_digit())
            {
                i += 2;
            } else if t == "?" {
                i += 1;
            } else if t == "as" {
                i += 1;
                if tok_is_ident(self.tok(i)) {
                    i += 1;
                }
            } else {
                break;
            }
        }
        (if keep_unit { unit } else { None }, i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;
    use crate::scan::scan;

    fn conflicts(body: &str) -> Vec<(usize, Unit, String, Unit)> {
        let toks = lex(&scan(body).masked);
        let mut sc = UnitScanner::new(&toks, toks.len());
        sc.scan_region(0, toks.len());
        sc.conflicts
            .into_iter()
            .map(|c| (c.line, c.left, c.op, c.right))
            .collect()
    }

    #[test]
    fn cross_unit_add_flagged() {
        let c = conflicts("let total = used_bytes + max_tokens;\n");
        assert_eq!(c.len(), 1);
        assert_eq!((c[0].1, c[0].2.as_str(), c[0].3), ("bytes", "+", "tokens"));
    }

    #[test]
    fn same_unit_and_unitless_clean() {
        assert!(conflicts("let t = used_bytes + cold_bytes;\n").is_empty());
        assert!(conflicts("let t = used_bytes + 4096;\n").is_empty());
    }

    #[test]
    fn converter_call_returns_true_unit() {
        assert!(conflicts("let b = used_bytes + spec.bytes_for_tokens(n_tokens);\n").is_empty());
        // Without the converter, tokens + bytes conflicts.
        let c = conflicts("let b = used_bytes + n_tokens;\n");
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn ratio_multiplication_converts() {
        assert!(conflicts("let b = used_bytes + n_tokens * spec.bytes_per_token();\n").is_empty());
        assert!(conflicts("seq_bytes += tokens as u64 * spec.bytes_per_token();\n").is_empty());
    }

    #[test]
    fn unit_propagates_through_let() {
        let c = conflicts("let held = used_bytes;\nlet x = held + n_tokens;\n");
        assert_eq!(c.len(), 1);
        assert_eq!((c[0].1, c[0].3), ("bytes", "tokens"));
        assert_eq!(c[0].0, 2);
    }

    #[test]
    fn suffix_on_binding_wins_over_initializer() {
        // `let n_tokens = raw_bytes / 16` would taint by initializer; the
        // declared suffix is authoritative and division clears units anyway.
        assert!(conflicts("let n_tokens = raw_bytes / 16;\nlet y = n_tokens + max_tokens;\n").is_empty());
    }

    #[test]
    fn comparisons_conflict_and_yield_unitless() {
        let c = conflicts("if used_bytes < max_tokens { f(); }\n");
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].2, "<");
    }

    #[test]
    fn as_cast_preserves_unit() {
        let c = conflicts("let x = used_bytes as usize + n_tokens;\n");
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn nested_args_scanned() {
        let c = conflicts("take(used_bytes + n_tokens);\n");
        assert_eq!(c.len(), 1);
    }
}
