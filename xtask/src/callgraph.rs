//! Intra-crate call graph and hot-root reachability.
//!
//! The crate model pools every analyzed file's item tree plus the
//! cross-artifact aux inputs (miri test list, parity test list, DESIGN.md).
//! Call edges are extracted per fn body and resolved through a precision
//! ladder (see [`reachable_from_hot_roots`]); reachability is a plain BFS
//! from the serving hot roots (`Batcher::step`, any `step_fused`,
//! `ServingEngine::decode`).
//!
//! Resolution is deliberately heuristic — no type inference, no trait
//! solving. The ladder is tuned so that *imprecision over-approximates*
//! (dynamic dispatch fans out to every same-named fn) except where a
//! std-prelude name collision would drown the lint in false edges
//! (`METHOD_EDGE_DENY`), where the fallback is no edge and the per-file
//! lints still cover the callee body if it is independently reachable.
//!
//! Keep in lockstep with the `callgraph` section of
//! `tools/lint_mirror.py`.

use std::collections::{HashMap, HashSet};

use crate::items::{file_mod_path, parse_items, FnItem, StructItem};
use crate::lexer::{lex, skip_angle, tok_is_ident, Tok};
use crate::lints::lint_ok;
use crate::scan::{scan, Scanned};

/// Cross-artifact aux inputs consumed by the whole-program lints. In repo
/// mode they are read from disk; in fixture mode a `//=== file: <path>`
/// section with one of these paths overrides them (absent = empty).
pub const AUX_MIRI: &str = "rust/tests/miri_kernels.rs";
pub const AUX_PARITY: &str = "rust/tests/kernel_parity_test.rs";
pub const AUX_DESIGN: &str = "DESIGN.md";
pub const AUX_PATHS: [&str; 3] = [AUX_MIRI, AUX_PARITY, AUX_DESIGN];

/// The serving hot roots: (fn name, required impl ctx or None for any).
pub const HOT_ROOTS: [(&str, Option<&str>); 4] = [
    ("step", Some("Batcher")),
    ("step_fused", None),
    ("decode", Some("ServingEngine")),
    // The fleet dispatcher's per-submission routing decision: fingerprint
    // scan + least-loaded fallback, run for every request entering the
    // fleet. It reads caller-built load snapshots precisely so it can stay
    // allocation- and lock-free.
    ("route_request", Some("FleetDispatch")),
];

/// Method names that collide with std-prelude methods: a `.name(..)` call
/// on an unknown receiver must NOT resolve intra-crate through these —
/// `.clone()` on a String would otherwise edge into any crate type's
/// `clone`, and `.err()` on a Result would edge into `Parser::err`.
/// (Qualified `Type::name(..)` calls still resolve normally.)
const METHOD_EDGE_DENY: [&str; 69] = [
    "clone", "to_vec", "to_string", "to_owned", "collect", "expect", "unwrap", "unwrap_or",
    "unwrap_or_else", "unwrap_or_default", "into", "from", "try_from", "try_into", "default",
    "new", "len", "is_empty", "iter", "iter_mut", "into_iter", "push", "pop", "insert", "remove",
    "get", "get_mut", "contains", "contains_key", "map", "map_err", "and_then", "or_else", "ok",
    "err", "ok_or", "ok_or_else", "as_ref", "as_mut", "as_slice", "as_str", "parse", "min",
    "max", "abs", "clamp", "fmt", "eq", "cmp", "partial_cmp", "hash", "next", "extend", "clear",
    "drain", "take", "replace", "write", "read", "flush", "send", "recv", "lock", "borrow",
    "borrow_mut", "join", "spawn", "wait", "drop",
];

fn method_edge_denied(name: &str) -> bool {
    METHOD_EDGE_DENY.contains(&name)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CallKind {
    Free,
    Qualified,
    Method,
}

#[derive(Debug, Clone)]
pub struct CallEdge {
    pub name: String,
    pub kind: CallKind,
    /// Qualifier: the `Qual` of `Qual::name(..)` (with `Self` mapped to the
    /// caller's ctx) or the receiver token of `recv.name(..)`.
    pub qual: Option<String>,
    pub line: usize,
    /// Token index of the callee name — lets the concurrency stage relate
    /// call sites to guard live ranges.
    pub idx: usize,
}

/// One analyzed file: scan output, token stream, and item tree.
pub struct FileModel {
    pub rel: String,
    pub scanned: Scanned,
    pub toks: Vec<Tok>,
    pub fns: Vec<FnItem>,
    pub structs: Vec<StructItem>,
}

/// The whole-crate view the whole-program lints run against.
pub struct CrateModel {
    pub files: Vec<FileModel>,
    pub aux: HashMap<String, String>,
    /// Names declared in any trait (dynamic-dispatch over-approximation).
    pub trait_methods: HashSet<String>,
    /// struct name -> field name -> first type token.
    pub field_types: HashMap<String, HashMap<String, String>>,
    pub struct_names: HashSet<String>,
}

impl CrateModel {
    pub fn build(file_pairs: &[(String, String)], aux: HashMap<String, String>) -> CrateModel {
        let mut files = Vec::new();
        let mut trait_methods = HashSet::new();
        let mut field_types: HashMap<String, HashMap<String, String>> = HashMap::new();
        let mut struct_names = HashSet::new();
        for (rel, src) in file_pairs {
            let scanned = scan(src);
            let toks = lex(&scanned.masked);
            let (mut fns, structs, traits) = parse_items(&toks, &scanned);
            let mod_path = file_mod_path(rel);
            for f in &mut fns {
                let mut mods = mod_path.clone();
                mods.extend(f.mods.drain(..));
                f.mods = mods;
            }
            trait_methods.extend(traits);
            for st in &structs {
                struct_names.insert(st.name.clone());
                let entry = field_types.entry(st.name.clone()).or_default();
                for (fname, _, fty) in &st.fields {
                    entry.insert(fname.clone(), fty.clone());
                }
            }
            files.push(FileModel {
                rel: rel.clone(),
                scanned,
                toks,
                fns,
                structs,
            });
        }
        CrateModel {
            files,
            aux,
            trait_methods,
            field_types,
            struct_names,
        }
    }

    pub fn aux_text(&self, path: &str) -> &str {
        self.aux.get(path).map(String::as_str).unwrap_or("")
    }
}

pub fn fn_label(f: &FnItem) -> String {
    match &f.ctx {
        Some(c) => format!("{c}::{}", f.name),
        None => f.name.clone(),
    }
}

/// `(callee, kind, qualifier, line, token idx)` call sites in the fn body.
pub fn call_edges(toks: &[Tok], f: &FnItem) -> Vec<CallEdge> {
    let mut edges = Vec::new();
    let (start, end) = f.body;
    let mut i = start;
    while i < end {
        let t = toks[i].text.as_str();
        let ln = toks[i].line;
        if tok_is_ident(t) {
            let mut k = i + 1;
            // Turbofish: `name::<T>(..)`.
            if k < end && toks[k].text == "::" && k + 1 < end && toks[k + 1].text == "<" {
                k = skip_angle(toks, k + 1);
            }
            if k < end && toks[k].text == "(" {
                let prev = if i > 0 { toks[i - 1].text.as_str() } else { "" };
                if prev == "fn" {
                    i += 1;
                    continue;
                }
                if prev == "." {
                    let recv = if i >= 2 { toks[i - 2].text.clone() } else { String::new() };
                    edges.push(CallEdge {
                        name: t.to_string(),
                        kind: CallKind::Method,
                        qual: Some(recv),
                        line: ln,
                        idx: i,
                    });
                } else if prev == "::" && i >= 2 && tok_is_ident(&toks[i - 2].text) {
                    let q = toks[i - 2].text.as_str();
                    if q == "Self" && f.ctx.is_some() {
                        edges.push(CallEdge {
                            name: t.to_string(),
                            kind: CallKind::Qualified,
                            qual: f.ctx.clone(),
                            line: ln,
                            idx: i,
                        });
                    } else if matches!(q, "self" | "crate" | "super" | "Self") {
                        edges.push(CallEdge {
                            name: t.to_string(),
                            kind: CallKind::Free,
                            qual: None,
                            line: ln,
                            idx: i,
                        });
                    } else {
                        edges.push(CallEdge {
                            name: t.to_string(),
                            kind: CallKind::Qualified,
                            qual: Some(q.to_string()),
                            line: ln,
                            idx: i,
                        });
                    }
                } else {
                    edges.push(CallEdge {
                        name: t.to_string(),
                        kind: CallKind::Free,
                        qual: None,
                        line: ln,
                        idx: i,
                    });
                }
            }
        }
        i += 1;
    }
    edges
}

/// `(nodes, name → candidate nodes)` over non-test fns — the shared
/// substrate for every call-graph-driven pass (reachability, concurrency).
pub fn build_call_index(
    model: &CrateModel,
) -> (Vec<(usize, usize)>, HashMap<String, Vec<(usize, usize)>>) {
    let mut index: HashMap<String, Vec<(usize, usize)>> = HashMap::new();
    let mut nodes: Vec<(usize, usize)> = Vec::new();
    for (fi, f) in model.files.iter().enumerate() {
        for (gi, fnm) in f.fns.iter().enumerate() {
            if fnm.is_test {
                continue;
            }
            nodes.push((fi, gi));
            index.entry(fnm.name.clone()).or_default().push((fi, gi));
        }
    }
    (nodes, index)
}

/// Resolution ladder shared by reachability and the concurrency stage,
/// most precise first:
///
///   1. `self.name(..)` → the caller's own impl.
///   2. `field.name(..)` where the caller's struct declares `field: Ty`
///      and `Ty` is a crate struct → Ty's impl (precise even for
///      std-colliding names like `insert`).
///   3. std-prelude collisions (METHOD_EDGE_DENY) → no edge.
///   4. trait-declared names → ALL same-named fns (dynamic dispatch:
///      over-approximation is the conservative answer).
///   5. otherwise → edge only if the name is crate-unique; an ambiguous
///      name would fan one `.load(..)` into every `load`.
pub fn resolve_call(
    model: &CrateModel,
    index: &HashMap<String, Vec<(usize, usize)>>,
    edge: &CallEdge,
    caller_ctx: Option<&str>,
) -> Vec<(usize, usize)> {
    let fn_at = |node: (usize, usize)| -> &FnItem { &model.files[node.0].fns[node.1] };
    let cands: &[(usize, usize)] = index.get(&edge.name).map(Vec::as_slice).unwrap_or(&[]);
    match edge.kind {
        CallKind::Qualified => {
            let qual = edge.qual.as_deref().unwrap_or("");
            cands
                .iter()
                .copied()
                .filter(|&n| {
                    let f = fn_at(n);
                    f.ctx.as_deref() == Some(qual) || f.mods.iter().any(|m| m == qual)
                })
                .collect()
        }
        CallKind::Free => {
            // Single-letter names are overwhelmingly closure/fn-pointer
            // parameters (`f(lo, hi)`), not crate free fns — never
            // resolve.
            if edge.name.len() == 1 {
                return Vec::new();
            }
            cands.iter().copied().filter(|&n| fn_at(n).ctx.is_none()).collect()
        }
        CallKind::Method => {
            let qual = edge.qual.as_deref().unwrap_or("");
            if qual == "self" {
                if let Some(ctx) = caller_ctx {
                    let same: Vec<(usize, usize)> = cands
                        .iter()
                        .copied()
                        .filter(|&n| fn_at(n).ctx.as_deref() == Some(ctx))
                        .collect();
                    if !same.is_empty() {
                        return same;
                    }
                }
            }
            let recv_ty = caller_ctx
                .and_then(|c| model.field_types.get(c))
                .and_then(|m| m.get(qual));
            if let Some(ty) = recv_ty {
                if model.struct_names.contains(ty) {
                    return cands
                        .iter()
                        .copied()
                        .filter(|&n| fn_at(n).ctx.as_deref() == Some(ty.as_str()))
                        .collect();
                }
            }
            if method_edge_denied(&edge.name) {
                return Vec::new();
            }
            if model.trait_methods.contains(&edge.name) {
                return cands.to_vec();
            }
            if cands.len() == 1 {
                cands.to_vec()
            } else {
                Vec::new()
            }
        }
    }
}

/// `{(file_idx, fn_idx): sorted root labels}` over non-test fns.
pub fn reachable_from_hot_roots(model: &CrateModel) -> HashMap<(usize, usize), Vec<String>> {
    let (nodes, index) = build_call_index(model);
    let fn_at = |node: (usize, usize)| -> &FnItem { &model.files[node.0].fns[node.1] };

    let mut edges_of: HashMap<(usize, usize), Vec<(usize, usize)>> = HashMap::new();
    for &(fi, gi) in &nodes {
        let f = &model.files[fi];
        let fnm = &f.fns[gi];
        let mut resolved = Vec::new();
        for e in call_edges(&f.toks, fnm) {
            // Annotated call line: edge cut (opt-in debug routes, backend
            // marshaling — the dyn-dispatch false path).
            if lint_ok(&f.scanned, e.line, "hot-path-alloc") {
                continue;
            }
            resolved.extend(resolve_call(model, &index, &e, fnm.ctx.as_deref()));
        }
        edges_of.insert((fi, gi), resolved);
    }

    let mut roots = Vec::new();
    for &(fi, gi) in &nodes {
        let fnm = &model.files[fi].fns[gi];
        for (rname, rctx) in HOT_ROOTS {
            if fnm.name == rname && (rctx.is_none() || fnm.ctx.as_deref() == rctx) {
                roots.push((fi, gi));
                break;
            }
        }
    }

    let mut reach: HashMap<(usize, usize), HashSet<String>> = HashMap::new();
    for &root in &roots {
        let label = fn_label(fn_at(root));
        let mut seen: HashSet<(usize, usize)> = HashSet::new();
        seen.insert(root);
        let mut stack = vec![root];
        while let Some(node) = stack.pop() {
            reach.entry(node).or_default().insert(label.clone());
            for &nxt in edges_of.get(&node).map(Vec::as_slice).unwrap_or(&[]) {
                if seen.insert(nxt) {
                    stack.push(nxt);
                }
            }
        }
    }
    reach
        .into_iter()
        .map(|(k, v)| {
            let mut labels: Vec<String> = v.into_iter().collect();
            labels.sort();
            (k, labels)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(files: &[(&str, &str)]) -> CrateModel {
        let pairs: Vec<(String, String)> = files
            .iter()
            .map(|(r, s)| (r.to_string(), s.to_string()))
            .collect();
        CrateModel::build(&pairs, HashMap::new())
    }

    fn reachable_names(m: &CrateModel) -> Vec<String> {
        let mut names: Vec<String> = reachable_from_hot_roots(m)
            .keys()
            .map(|&(fi, gi)| fn_label(&m.files[fi].fns[gi]))
            .collect();
        names.sort();
        names
    }

    #[test]
    fn transitive_reachability_from_batcher_step() {
        let m = model(&[(
            "rust/src/coordinator/batcher.rs",
            "impl Batcher {\n  fn step(&mut self) { self.admit(); }\n  fn admit(&mut self) { helper(); }\n}\nfn helper() { leaf(); }\nfn leaf() {}\nfn unrelated() {}\n",
        )]);
        let names = reachable_names(&m);
        assert!(names.contains(&"Batcher::step".to_string()));
        assert!(names.contains(&"Batcher::admit".to_string()));
        assert!(names.contains(&"helper".to_string()));
        assert!(names.contains(&"leaf".to_string()));
        assert!(!names.contains(&"unrelated".to_string()));
    }

    #[test]
    fn qualified_calls_resolve_via_module_path() {
        let m = model(&[
            (
                "rust/src/coordinator/batcher.rs",
                "impl Batcher {\n  fn step(&mut self) { crate::attn::decode_attn(); }\n}\n",
            ),
            ("rust/src/attn/mod.rs", "pub fn decode_attn() { inner(); }\nfn inner() {}\n"),
        ]);
        let names = reachable_names(&m);
        assert!(names.contains(&"decode_attn".to_string()));
        assert!(names.contains(&"inner".to_string()));
    }

    #[test]
    fn std_colliding_method_does_not_fan_out() {
        let m = model(&[(
            "rust/src/coordinator/batcher.rs",
            "impl Batcher {\n  fn step(&mut self) { self.q.insert(1); }\n}\nimpl Trie {\n  fn insert(&mut self) { deep(); }\n}\nfn deep() {}\n",
        )]);
        // `q` is not a known field of Batcher, `insert` is std-colliding:
        // no edge, Trie::insert stays unreachable.
        let names = reachable_names(&m);
        assert!(!names.contains(&"Trie::insert".to_string()));
        assert!(!names.contains(&"deep".to_string()));
    }

    #[test]
    fn field_type_inference_beats_deny_list() {
        let m = model(&[(
            "rust/src/coordinator/batcher.rs",
            "struct Batcher { trie: Trie }\nstruct Trie { n: usize }\nimpl Batcher {\n  fn step(&mut self) { self.trie.insert(1); }\n}\nimpl Trie {\n  fn insert(&mut self, x: usize) { deep(); }\n}\nfn deep() {}\n",
        )]);
        let names = reachable_names(&m);
        assert!(names.contains(&"Trie::insert".to_string()));
        assert!(names.contains(&"deep".to_string()));
    }

    #[test]
    fn trait_methods_over_approximate() {
        let m = model(&[(
            "rust/src/server/engine.rs",
            "trait Engine {\n  fn alloc_with_prompt(&mut self);\n}\nimpl Batcher {\n  fn step(&mut self) { self.engine.alloc_with_prompt(); }\n}\nimpl RealEngine {\n  fn alloc_with_prompt(&mut self) { leaf(); }\n}\nfn leaf() {}\n",
        )]);
        let names = reachable_names(&m);
        assert!(names.contains(&"RealEngine::alloc_with_prompt".to_string()));
        assert!(names.contains(&"leaf".to_string()));
    }

    #[test]
    fn lint_ok_on_call_line_cuts_the_edge() {
        let m = model(&[(
            "rust/src/coordinator/batcher.rs",
            "impl Batcher {\n  fn step(&mut self) {\n    // lint-ok(hot-path-alloc): debug route\n    debug_route();\n  }\n}\nfn debug_route() { leaf(); }\nfn leaf() {}\n",
        )]);
        let names = reachable_names(&m);
        assert!(!names.contains(&"debug_route".to_string()));
        assert!(!names.contains(&"leaf".to_string()));
    }

    #[test]
    fn test_fns_are_not_roots_or_nodes() {
        let m = model(&[(
            "rust/src/server/engine.rs",
            "#[cfg(test)]\nmod tests {\n  fn step_fused() { helper(); }\n}\nfn helper() {}\n",
        )]);
        assert!(reachable_names(&m).is_empty());
    }
}
