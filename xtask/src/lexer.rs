//! Token lexer over a masked source (the output of [`crate::scan::scan`]).
//!
//! Masking already removed comments and string/char bodies while preserving
//! line structure, so lexing is a single forward pass: identifier runs
//! (including keywords and integer literals — the item walker tells them
//! apart by position), multi-character operators longest-first, and every
//! other byte as a one-character token. Each token carries its 1-based line.
//!
//! Also home to the token-level delimiter matchers shared by the item
//! walker, the call-graph builder, and the unit scanner. All matchers are
//! fail-safe: unbalanced input returns a best-effort index (end of stream)
//! rather than panicking, which can only over-approximate spans — lints
//! built on top fail toward *extra* findings, never silence.
//!
//! Keep in lockstep with the `lex` section of `tools/lint_mirror.py`.

use crate::scan::is_ident;

/// One token of a masked source file.
#[derive(Debug, Clone)]
pub struct Tok {
    pub text: String,
    /// 1-based source line the token starts on.
    pub line: usize,
}

const OPS3: [&str; 3] = ["..=", "<<=", ">>="];
const OPS2: [&str; 17] = [
    "::", "->", "=>", "..", "+=", "-=", "*=", "/=", "%=", "==", "!=", "<=", ">=", "&&", "||",
    "<<", ">>",
];

pub fn lex(masked: &str) -> Vec<Tok> {
    let b = masked.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
        } else if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
        } else if is_ident(c) {
            let start = i;
            while i < n && is_ident(b[i]) {
                i += 1;
            }
            toks.push(Tok {
                text: masked[start..i].to_string(),
                line,
            });
        } else {
            let three = &masked[i..(i + 3).min(n)];
            let two = &masked[i..(i + 2).min(n)];
            if OPS3.contains(&three) {
                toks.push(Tok {
                    text: three.to_string(),
                    line,
                });
                i += 3;
            } else if OPS2.contains(&two) {
                toks.push(Tok {
                    text: two.to_string(),
                    line,
                });
                i += 2;
            } else {
                toks.push(Tok {
                    text: (c as char).to_string(),
                    line,
                });
                i += 1;
            }
        }
    }
    toks
}

/// True when the token is an identifier (or keyword): ident-char start,
/// not a digit — integer literals lex as ident runs but are not names.
pub fn tok_is_ident(t: &str) -> bool {
    let b = t.as_bytes();
    !b.is_empty() && is_ident(b[0]) && !b[0].is_ascii_digit()
}

/// `toks[i] == "<"`: index just past the matching `>`. Fail-safe: on `{`,
/// `;`, or exhaustion give up and return `i + 1` (callers re-scan) — a `<`
/// that was a comparison, not a generic bracket, must not swallow the rest
/// of the body.
pub fn skip_angle(toks: &[Tok], i: usize) -> usize {
    let mut depth = 0i64;
    let mut j = i;
    let n = toks.len();
    while j < n {
        match toks[j].text.as_str() {
            "<" => depth += 1,
            "<<" => depth += 2,
            ">" => {
                depth -= 1;
                if depth == 0 {
                    return j + 1;
                }
            }
            ">>" => {
                depth -= 2;
                if depth <= 0 {
                    return j + 1;
                }
            }
            "{" | ";" => return i + 1,
            _ => {}
        }
        j += 1;
    }
    i + 1
}

fn match_delim_toks(toks: &[Tok], i: usize, open: &str, close: &str) -> usize {
    let mut depth = 0i64;
    let mut j = i;
    let n = toks.len();
    while j < n {
        let t = toks[j].text.as_str();
        if t == open {
            depth += 1;
        } else if t == close {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
        j += 1;
    }
    n.saturating_sub(1)
}

/// `toks[i] == "{"`: index of the matching `}` (fail-safe: last token).
pub fn match_brace_toks(toks: &[Tok], i: usize) -> usize {
    match_delim_toks(toks, i, "{", "}")
}

/// `toks[i] == "("`: index of the matching `)` (fail-safe: last token).
pub fn match_paren_toks(toks: &[Tok], i: usize) -> usize {
    match_delim_toks(toks, i, "(", ")")
}

/// `toks[i] == "["`: index of the matching `]` (fail-safe: last token).
pub fn match_bracket_toks(toks: &[Tok], i: usize) -> usize {
    match_delim_toks(toks, i, "[", "]")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::scan;

    fn texts(src: &str) -> Vec<String> {
        lex(&scan(src).masked).into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_ops_and_lines() {
        let toks = lex(&scan("a::b -> c\nx += 1..=2;\n").masked);
        let t: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(t, vec!["a", "::", "b", "->", "c", "x", "+=", "1", "..=", "2", ";"]);
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[5].line, 2);
    }

    #[test]
    fn shift_ops_lex_whole() {
        assert_eq!(texts("x << y >> z <<= w"), vec!["x", "<<", "y", ">>", "z", "<<=", "w"]);
    }

    #[test]
    fn comments_and_strings_do_not_tokenize() {
        let t = texts("let s = \"a + b\"; // c + d\n");
        assert_eq!(t, vec!["let", "s", "=", ";"]);
    }

    #[test]
    fn angle_matching_nested_and_failsafe() {
        let toks = lex(&scan("Vec<Vec<u8>> x").masked);
        // toks: Vec < Vec < u8 >> x — skip from the first '<' lands on `x`.
        assert_eq!(toks[skip_angle(&toks, 1)].text, "x");
        // A comparison '<' followed by ';' bails out one past the '<'.
        let cmp = lex(&scan("a < b; c").masked);
        assert_eq!(skip_angle(&cmp, 1), 2);
    }

    #[test]
    fn delim_matching() {
        let toks = lex(&scan("f(a, (b), c)[i]{ d }").masked);
        let open_paren = toks.iter().position(|t| t.text == "(").unwrap();
        let close = match_paren_toks(&toks, open_paren);
        assert_eq!(toks[close].text, ")");
        assert_eq!(toks[close + 1].text, "[");
        assert_eq!(toks[match_bracket_toks(&toks, close + 1)].text, "]");
        let open_brace = toks.iter().position(|t| t.text == "{").unwrap();
        assert_eq!(match_brace_toks(&toks, open_brace), toks.len() - 1);
    }

    #[test]
    fn ident_classification() {
        assert!(tok_is_ident("foo_1"));
        assert!(tok_is_ident("_x"));
        assert!(!tok_is_ident("1foo"));
        assert!(!tok_is_ident("::"));
        assert!(!tok_is_ident(""));
    }
}
