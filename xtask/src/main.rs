//! `cargo xtask` — repo-specific correctness tooling.
//!
//! Subcommands:
//!
//! * `cargo xtask lint` — run the five structural lints (see [`lints`])
//!   over `rust/src`. Exits non-zero, listing `file:line: [rule] message`
//!   findings, when the tree is not clean.
//! * `cargo xtask fixtures` — self-test: lint every negative fixture under
//!   `xtask/fixtures/` and verify each one trips exactly the rule named in
//!   its `// expect-lint:` header (`none` for the clean control). Exits
//!   non-zero if a fixture fails to trip — i.e. if the lint harness itself
//!   has gone blind.
//!
//! The harness is wired as a workspace member with the conventional
//! `.cargo/config.toml` alias, and runs as the blocking `lint-xtask` CI
//! job. DESIGN.md §9 documents the rules and how to extend them.

mod lints;
mod scan;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint_tree(),
        Some("fixtures") => check_fixtures(),
        _ => {
            eprintln!("usage: cargo xtask <lint|fixtures>");
            ExitCode::FAILURE
        }
    }
}

/// Repo root: the parent of this crate's manifest dir.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives one level below the repo root")
        .to_path_buf()
}

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    let mut entries: Vec<_> = entries.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rust_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn lint_tree() -> ExitCode {
    let root = repo_root();
    let mut files = Vec::new();
    rust_files(&root.join("rust/src"), &mut files);
    if files.is_empty() {
        eprintln!("xtask lint: no Rust sources found under {}", root.display());
        return ExitCode::FAILURE;
    }
    let mut findings = 0usize;
    for path in &files {
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let Ok(src) = std::fs::read_to_string(path) else {
            eprintln!("xtask lint: unreadable file {}", path.display());
            findings += 1;
            continue;
        };
        for f in lints::lint_source(&rel, &src) {
            println!("{rel}:{}: [{}] {}", f.line, f.rule, f.msg);
            findings += 1;
        }
    }
    if findings == 0 {
        println!("xtask lint: {} files clean", files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("xtask lint: {findings} finding(s)");
        ExitCode::FAILURE
    }
}

/// Parse a fixture's `// lint-as:` (virtual repo path) and
/// `// expect-lint:` (rule name or `none`) headers.
fn fixture_headers(src: &str) -> Option<(String, String)> {
    let mut lint_as = None;
    let mut expect = None;
    for line in src.lines().take(10) {
        if let Some(v) = line.strip_prefix("// lint-as:") {
            lint_as = Some(v.trim().to_string());
        }
        if let Some(v) = line.strip_prefix("// expect-lint:") {
            expect = Some(v.trim().to_string());
        }
    }
    Some((lint_as?, expect?))
}

fn run_fixture(path: &Path) -> Result<(), String> {
    let src = std::fs::read_to_string(path).map_err(|e| format!("unreadable: {e}"))?;
    let (lint_as, expect) =
        fixture_headers(&src).ok_or("missing `// lint-as:` / `// expect-lint:` headers")?;
    if expect != "none" && !lints::RULES.contains(&expect.as_str()) {
        return Err(format!("unknown rule `{expect}` in expect-lint header"));
    }
    let findings = lints::lint_source(&lint_as, &src);
    if expect == "none" {
        if findings.is_empty() {
            return Ok(());
        }
        return Err(format!(
            "clean control fixture tripped {} finding(s): first = line {} [{}]",
            findings.len(),
            findings[0].line,
            findings[0].rule
        ));
    }
    if findings.iter().any(|f| f.rule == expect) {
        Ok(())
    } else {
        Err(format!(
            "expected a `{expect}` finding but got {:?}",
            findings.iter().map(|f| f.rule).collect::<Vec<_>>()
        ))
    }
}

fn check_fixtures() -> ExitCode {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures");
    let mut files = Vec::new();
    rust_files(&dir, &mut files);
    if files.is_empty() {
        eprintln!("xtask fixtures: none found under {}", dir.display());
        return ExitCode::FAILURE;
    }
    let mut failed = 0usize;
    for f in &files {
        let name = f.file_name().unwrap_or_default().to_string_lossy();
        match run_fixture(f) {
            Ok(()) => println!("fixture {name}: ok"),
            Err(e) => {
                eprintln!("fixture {name}: FAILED — {e}");
                failed += 1;
            }
        }
    }
    if failed == 0 {
        println!("xtask fixtures: {} fixture(s) verified", files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("xtask fixtures: {failed} fixture(s) failed");
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every committed fixture must behave as declared — this is the same
    /// check as `cargo xtask fixtures`, wired into `cargo test -p xtask`.
    #[test]
    fn all_fixtures_trip_their_rule() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures");
        let mut files = Vec::new();
        rust_files(&dir, &mut files);
        assert!(!files.is_empty(), "fixtures directory missing or empty");
        for f in &files {
            if let Err(e) = run_fixture(f) {
                panic!("fixture {}: {e}", f.display());
            }
        }
    }

    /// The rule names the fixtures reference must stay in sync with
    /// the lint registry.
    #[test]
    fn fixture_coverage_spans_all_rules() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures");
        let mut files = Vec::new();
        rust_files(&dir, &mut files);
        let mut covered: Vec<String> = Vec::new();
        for f in &files {
            let src = std::fs::read_to_string(f).unwrap();
            let (_, expect) = fixture_headers(&src).unwrap();
            covered.push(expect);
        }
        for rule in lints::RULES {
            assert!(
                covered.iter().any(|c| c == rule),
                "no negative fixture covers rule `{rule}`"
            );
        }
        assert!(
            covered.iter().any(|c| c == "none"),
            "no clean control fixture"
        );
    }
}
