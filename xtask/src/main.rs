//! `cargo xtask` — repo-specific correctness tooling.
//!
//! Subcommands:
//!
//! * `cargo xtask lint [--format human|json|sarif] [--rule <id>]` — run
//!   the thirteen structural lints (see [`lints`]) over `rust/src`, with
//!   the cross-artifact aux inputs (`rust/tests/miri_kernels.rs`,
//!   `rust/tests/kernel_parity_test.rs`, `DESIGN.md`) read from disk.
//!   Exits non-zero when the tree is not clean. `json` is a machine
//!   summary; `sarif` is SARIF 2.1.0 for code-scanning upload.
//!   `--rule <id>` reruns a single rule (iterating on one lint without
//!   wading through the rest); suppression counts stay whole-run.
//! * `cargo xtask fixtures [--emit-findings]` — self-test: lint every
//!   fixture under `xtask/fixtures/` and verify each one trips exactly the
//!   rule named in its `// expect-lint:` header (`none` for clean
//!   controls), then run the registration self-check (every rule id must
//!   have a fixture, a CI mention, and a DESIGN.md §9 row).
//!   `--emit-findings` instead prints the canonical
//!   `fixture|file|line|rule` lines used for cross-implementation
//!   agreement with `tools/lint_mirror.py`.
//!
//! Fixtures may carry extra virtual files: a `//=== file: <path>` line
//! starts a new section; sections whose path is one of the aux artifacts
//! override that artifact, any other section becomes an additional crate
//! file (so call-graph and cross-artifact rules are exercisable from a
//! single fixture file).
//!
//! The harness is wired as a workspace member with the conventional
//! `.cargo/config.toml` alias, and runs as the blocking `lint-xtask` CI
//! job; `tools/lint_mirror.py` is the toolchain-free mirror that must stay
//! finding-for-finding identical (the `mirror_agrees_on_fixtures` test and
//! the `lint-mirror` CI job enforce it). DESIGN.md §9 documents the rules
//! and how to extend them.

mod callgraph;
mod concurrency;
mod items;
mod lexer;
mod lints;
mod scan;
mod units;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if !args.is_empty() && !args[0].starts_with('-') {
        args.remove(0)
    } else {
        "lint".to_string()
    };
    let mut fmt = "human".to_string();
    let mut emit = false;
    let mut rule: Option<String> = None;
    let mut i = 0usize;
    while i < args.len() {
        let a = args[i].as_str();
        if a == "--format" && i + 1 < args.len() {
            fmt = args[i + 1].clone();
            i += 2;
        } else if let Some(v) = a.strip_prefix("--format=") {
            fmt = v.to_string();
            i += 1;
        } else if a == "--rule" && i + 1 < args.len() {
            rule = Some(args[i + 1].clone());
            i += 2;
        } else if let Some(v) = a.strip_prefix("--rule=") {
            rule = Some(v.to_string());
            i += 1;
        } else if a == "--emit-findings" {
            emit = true;
            i += 1;
        } else {
            eprintln!(
                "usage: cargo xtask <lint|fixtures> [--format human|json|sarif] [--rule <id>] [--emit-findings]"
            );
            return ExitCode::from(2);
        }
    }
    if let Some(r) = &rule {
        if !lints::RULES.contains(&r.as_str()) {
            eprintln!("xtask: unknown rule `{r}` (known: {})", lints::RULES.join(", "));
            return ExitCode::from(2);
        }
    }
    match cmd.as_str() {
        "lint" => lint_tree(&fmt, rule.as_deref()),
        "fixtures" => check_fixtures(emit),
        other => {
            eprintln!("unknown command `{other}`");
            ExitCode::from(2)
        }
    }
}

/// Repo root: the parent of this crate's manifest dir.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives one level below the repo root")
        .to_path_buf()
}

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    let mut entries: Vec<_> = entries.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rust_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// Aux artifacts read from the repo (absent file = empty, the rules then
/// report the missing coverage as findings rather than erroring).
fn read_aux_from_repo(root: &Path) -> HashMap<String, String> {
    let mut aux = HashMap::new();
    for rel in callgraph::AUX_PATHS {
        if let Ok(text) = std::fs::read_to_string(root.join(rel)) {
            aux.insert(rel.to_string(), text);
        }
    }
    aux
}

fn lint_tree(fmt: &str, rule: Option<&str>) -> ExitCode {
    let root = repo_root();
    let mut paths = Vec::new();
    rust_files(&root.join("rust/src"), &mut paths);
    if paths.is_empty() {
        eprintln!("xtask lint: no Rust sources found under {}", root.display());
        return ExitCode::FAILURE;
    }
    let mut files = Vec::new();
    for path in &paths {
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        match std::fs::read_to_string(path) {
            Ok(src) => files.push((rel, src)),
            Err(e) => {
                eprintln!("xtask lint: unreadable file {}: {e}", path.display());
                return ExitCode::FAILURE;
            }
        }
    }
    let (mut findings, suppressed) = lints::lint_crate(&files, read_aux_from_repo(&root));
    if let Some(r) = rule {
        findings.retain(|f| f.rule == r);
    }
    match fmt {
        "json" => println!("{}", json_summary(&findings, suppressed, files.len())),
        "sarif" => println!("{}", sarif_report(&findings)),
        _ => {
            for f in &findings {
                println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
            }
            if findings.is_empty() {
                println!(
                    "xtask lint: {} files clean ({suppressed} finding(s) suppressed by lint-ok)",
                    files.len()
                );
            } else {
                eprintln!(
                    "xtask lint: {} finding(s), {suppressed} suppressed by lint-ok",
                    findings.len()
                );
            }
        }
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

// --- hand-rolled JSON (xtask has no dependencies by design) ----------------

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_summary(findings: &[lints::Finding], suppressed: usize, files: usize) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"files\": {files},\n"));
    out.push_str("  \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"file\": \"{}\", \"line\": {}, \"msg\": \"{}\", \"rule\": \"{}\"}}",
            json_escape(&f.file),
            f.line,
            json_escape(&f.msg),
            f.rule
        ));
    }
    if !findings.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("],\n");
    out.push_str(&format!("  \"suppressed\": {suppressed}\n}}"));
    out
}

/// SARIF 2.1.0, the shape code-scanning services ingest. Keys are emitted
/// in sorted order to match `tools/lint_mirror.py --format sarif`.
fn sarif_report(findings: &[lints::Finding]) -> String {
    let mut out = String::from("{\n");
    out.push_str(
        "  \"$schema\": \"https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/Schemata/sarif-schema-2.1.0.json\",\n",
    );
    out.push_str("  \"runs\": [\n    {\n      \"results\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n        {{\n          \"level\": \"error\",\n          \
             \"locations\": [{{\"physicalLocation\": {{\"artifactLocation\": \
             {{\"uri\": \"{}\"}}, \"region\": {{\"startLine\": {}}}}}}}],\n          \
             \"message\": {{\"text\": \"{}\"}},\n          \"ruleId\": \"{}\"\n        }}",
            json_escape(&f.file),
            f.line,
            json_escape(&f.msg),
            f.rule
        ));
    }
    if !findings.is_empty() {
        out.push_str("\n      ");
    }
    out.push_str("],\n");
    out.push_str("      \"tool\": {\n        \"driver\": {\n");
    out.push_str("          \"informationUri\": \"https://example.invalid/kqsvd/DESIGN.md\",\n");
    out.push_str("          \"name\": \"kqsvd-xtask-lint\",\n");
    out.push_str("          \"rules\": [");
    for (i, r) in lints::RULES.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!("{{\"id\": \"{r}\"}}"));
    }
    out.push_str("]\n        }\n      }\n    }\n  ],\n  \"version\": \"2.1.0\"\n}");
    out
}

// --- fixtures --------------------------------------------------------------

const SECTION_PREFIX: &str = "//=== file: ";

/// `(main_text, extra_files, aux)` — sections split on `//=== file:` lines.
fn split_fixture(text: &str) -> (String, Vec<(String, String)>, HashMap<String, String>) {
    let mut sections: Vec<(Option<String>, Vec<&str>)> = Vec::new();
    let mut cur_path: Option<String> = None;
    let mut cur: Vec<&str> = Vec::new();
    for line in text.split('\n') {
        if let Some(rest) = line.strip_prefix(SECTION_PREFIX) {
            sections.push((cur_path.take(), std::mem::take(&mut cur)));
            cur_path = Some(rest.trim().to_string());
        } else {
            cur.push(line);
        }
    }
    sections.push((cur_path, cur));
    let main = sections[0].1.join("\n");
    let mut extra = Vec::new();
    let mut aux = HashMap::new();
    for (path, body_lines) in sections.into_iter().skip(1) {
        let path = path.unwrap_or_default();
        let body = body_lines.join("\n");
        if callgraph::AUX_PATHS.contains(&path.as_str()) {
            aux.insert(path, body);
        } else {
            extra.push((path, body));
        }
    }
    (main, extra, aux)
}

/// Parse a fixture's `// lint-as:` (virtual repo path) and
/// `// expect-lint:` (rule name or `none`) headers.
fn fixture_headers(main: &str) -> Option<(String, String)> {
    let mut lint_as = None;
    let mut expect = None;
    for line in main.lines().take(10) {
        if let Some(v) = line.strip_prefix("// lint-as:") {
            lint_as = Some(v.trim().to_string());
        }
        if let Some(v) = line.strip_prefix("// expect-lint:") {
            expect = Some(v.trim().to_string());
        }
    }
    Some((lint_as?, expect?))
}

fn run_fixture_text(text: &str) -> Result<(Vec<lints::Finding>, String), String> {
    let (main, extra, aux) = split_fixture(text);
    let (lint_as, expect) = fixture_headers(&main)
        .ok_or_else(|| "missing `// lint-as:` / `// expect-lint:` headers".to_string())?;
    if expect != "none" && !lints::RULES.contains(&expect.as_str()) {
        return Err(format!("unknown rule `{expect}` in expect-lint header"));
    }
    let mut files = vec![(lint_as, main)];
    files.extend(extra);
    let (findings, _) = lints::lint_crate(&files, aux);
    Ok((findings, expect))
}

fn check_fixture(path: &Path) -> Result<(), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("unreadable: {e}"))?;
    let (findings, expect) = run_fixture_text(&text)?;
    if expect == "none" {
        if findings.is_empty() {
            return Ok(());
        }
        let f0 = &findings[0];
        return Err(format!(
            "clean control tripped {} finding(s): first = {}:{} [{}]",
            findings.len(),
            f0.file,
            f0.line,
            f0.rule
        ));
    }
    if findings.iter().any(|f| f.rule == expect) {
        Ok(())
    } else {
        Err(format!(
            "expected a `{expect}` finding but got {:?}",
            findings.iter().map(|f| f.rule).collect::<Vec<_>>()
        ))
    }
}

/// Canonical `fixture|file|line|rule` lines over the whole fixture corpus —
/// the agreement surface shared with `tools/lint_mirror.py`.
fn emit_fixture_findings(paths: &[PathBuf]) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    for path in paths {
        let name = path.file_name().unwrap_or_default().to_string_lossy();
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("fixture {name}: unreadable: {e}"))?;
        let (findings, _) = run_fixture_text(&text).map_err(|e| format!("fixture {name}: {e}"))?;
        for f in findings {
            out.push(format!("{name}|{}|{}|{}", f.file, f.line, f.rule));
        }
    }
    Ok(out)
}

/// Every rule id must appear in the fixture corpus (an `expect-lint`
/// header), be named in CI, and be documented in DESIGN.md §9 — adding a
/// lint without registering it everywhere is itself a lint failure.
fn registration_selfcheck(root: &Path, fixture_paths: &[PathBuf]) -> Vec<String> {
    let mut errors = Vec::new();
    let mut expects = Vec::new();
    for path in fixture_paths {
        let Ok(text) = std::fs::read_to_string(path) else {
            continue;
        };
        let (main, _, _) = split_fixture(&text);
        if let Some((_, expect)) = fixture_headers(&main) {
            expects.push(expect);
        }
    }
    let ci = std::fs::read_to_string(root.join(".github/workflows/ci.yml")).unwrap_or_default();
    let design = std::fs::read_to_string(root.join("DESIGN.md")).unwrap_or_default();
    let design_9 = lints::design_section(&design, "## §9");
    for rule in lints::RULES {
        if !expects.iter().any(|e| e == rule) {
            errors.push(format!("rule `{rule}` has no fixture (expect-lint header)"));
        }
        if !ci.contains(rule) {
            errors.push(format!("rule `{rule}` not named in .github/workflows/ci.yml"));
        }
        if !design_9.contains(rule) {
            errors.push(format!("rule `{rule}` not documented in DESIGN.md §9"));
        }
    }
    if !expects.iter().any(|e| e == "none") {
        errors.push("no clean control fixture (expect-lint: none)".to_string());
    }
    errors
}

fn check_fixtures(emit: bool) -> ExitCode {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures");
    let mut files = Vec::new();
    rust_files(&dir, &mut files);
    if files.is_empty() {
        eprintln!("xtask fixtures: none found under {}", dir.display());
        return ExitCode::FAILURE;
    }
    if emit {
        return match emit_fixture_findings(&files) {
            Ok(lines) => {
                for l in lines {
                    println!("{l}");
                }
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("xtask fixtures: {e}");
                ExitCode::FAILURE
            }
        };
    }
    let mut failed = 0usize;
    for f in &files {
        let name = f.file_name().unwrap_or_default().to_string_lossy();
        match check_fixture(f) {
            Ok(()) => println!("fixture {name}: ok"),
            Err(e) => {
                eprintln!("fixture {name}: FAILED — {e}");
                failed += 1;
            }
        }
    }
    for err in registration_selfcheck(&repo_root(), &files) {
        eprintln!("registration self-check: FAILED — {err}");
        failed += 1;
    }
    if failed == 0 {
        println!(
            "xtask fixtures: {} fixture(s) verified; registration self-check passed ({} rules)",
            files.len(),
            lints::RULES.len()
        );
        ExitCode::SUCCESS
    } else {
        eprintln!("xtask fixtures: {failed} failure(s)");
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture_paths() -> Vec<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures");
        let mut files = Vec::new();
        rust_files(&dir, &mut files);
        assert!(!files.is_empty(), "fixtures directory missing or empty");
        files
    }

    /// Every committed fixture must behave as declared — this is the same
    /// check as `cargo xtask fixtures`, wired into `cargo test -p xtask`.
    #[test]
    fn all_fixtures_trip_their_rule() {
        for f in fixture_paths() {
            if let Err(e) = check_fixture(&f) {
                panic!("fixture {}: {e}", f.display());
            }
        }
    }

    /// The rule names the fixtures reference must stay in sync with
    /// the lint registry.
    #[test]
    fn fixture_coverage_spans_all_rules() {
        let mut covered: Vec<String> = Vec::new();
        for f in fixture_paths() {
            let text = std::fs::read_to_string(&f).unwrap();
            let (main, _, _) = split_fixture(&text);
            let (_, expect) = fixture_headers(&main).unwrap();
            covered.push(expect);
        }
        for rule in lints::RULES {
            assert!(
                covered.iter().any(|c| c == rule),
                "no negative fixture covers rule `{rule}`"
            );
        }
        assert!(covered.iter().any(|c| c == "none"), "no clean control fixture");
    }

    /// Adding a lint means registering it in fixtures, CI, and DESIGN §9.
    #[test]
    fn registration_selfcheck_passes() {
        let errors = registration_selfcheck(&repo_root(), &fixture_paths());
        assert!(errors.is_empty(), "{errors:#?}");
    }

    /// `tools/lint_mirror.py` must agree finding-for-finding with this
    /// implementation over the whole fixture corpus. Canonical lines are
    /// `fixture|file|line|rule` — msg differences cannot hide here because
    /// ordering ties on msg only between lines that are otherwise
    /// identical. Skips (with a note) when python3 is unavailable.
    #[test]
    fn mirror_agrees_on_fixtures() {
        let root = repo_root();
        let ours = emit_fixture_findings(&fixture_paths()).expect("fixtures lint cleanly");
        let out = match std::process::Command::new("python3")
            .args(["tools/lint_mirror.py", "fixtures", "--emit-findings"])
            .current_dir(&root)
            .output()
        {
            Ok(out) => out,
            Err(e) => {
                eprintln!("skipping mirror agreement: python3 unavailable ({e})");
                return;
            }
        };
        assert!(
            out.status.success(),
            "lint_mirror.py failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let theirs: Vec<String> = String::from_utf8_lossy(&out.stdout)
            .lines()
            .map(|l| l.to_string())
            .collect();
        assert_eq!(
            ours, theirs,
            "xtask and tools/lint_mirror.py disagree on the fixture corpus"
        );
    }

    #[test]
    fn fixture_sections_split() {
        let text = "// lint-as: rust/src/a.rs\n// expect-lint: none\nfn main() {}\n\
                    //=== file: rust/tests/miri_kernels.rs\nfn t() {}\n\
                    //=== file: rust/src/b.rs\nfn b() {}\n";
        let (main, extra, aux) = split_fixture(text);
        assert!(main.contains("fn main"));
        assert_eq!(extra.len(), 1);
        assert_eq!(extra[0].0, "rust/src/b.rs");
        assert!(aux.contains_key(callgraph::AUX_MIRI));
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        // Findings text flows through untouched otherwise (incl. non-ASCII).
        assert_eq!(json_escape("§5e — ok"), "§5e — ok");
    }
}
