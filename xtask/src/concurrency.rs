//! Concurrency-protocol analysis: per-fn models of lock / condvar / atomic /
//! channel usage, propagated over the resolved call graph, powering the four
//! concurrency lints:
//!
//! * `lock-order` — every nested acquisition (a second `.lock()` / `.read()`
//!   / `.write()` while a guard is live, or a call to a fn whose transitive
//!   lock set is non-empty) adds a held → acquired edge to a global
//!   acquisition-order graph; any edge closing a cycle is a potential ABBA
//!   deadlock.
//! * `condvar-discipline` — `Condvar::wait`/`wait_timeout` must sit inside a
//!   `loop`/`while`/`for` body AND rebind the guard it is passed, so the
//!   predicate is re-checked under the lock; and a fn mutating state behind
//!   a mutex owned by a condvar-carrying struct must notify that condvar.
//! * `atomic-ordering` — `Ordering::Relaxed` only on sites annotated as
//!   monotonic counters/gauges; `AtomicBool` fields are flags (Acquire loads
//!   / Release stores / at-least-Acquire-or-Release RMWs); per atomic field
//!   the load and store ordering sets must each be consistent.
//! * `channel-lifecycle` — a `spawn(..)` whose `JoinHandle` is discarded in
//!   statement position, and `recv`/`recv_timeout`/`try_recv` chained into
//!   `.unwrap()`/`.expect(..)`.
//!
//! Lock and atomic receivers resolve to `Struct.field` identities through
//! the items pass's field table — only when exactly one non-test struct
//! declares the field; ambiguous names stay bare and opt out of cross-fn
//! reasoning rather than guess. Detection is purely structural: the
//! primitive method names (`lock`, `wait`, `send`, `recv`, `spawn`, `join`,
//! `drop`, …) are on the call-graph deny-list, so this stage finds them by
//! token shape, never via call edges.
//!
//! Keep in lockstep with the `concurrency stage` section of
//! `tools/lint_mirror.py`.

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};

use crate::callgraph::{build_call_index, call_edges, fn_label, resolve_call, CrateModel};
use crate::items::FnItem;
use crate::lexer::{tok_is_ident, Tok};
use crate::lints::Sink;

const LOCK_TYPES: [&str; 2] = ["Mutex", "RwLock"];
const ATOMIC_TYPES: [&str; 11] = [
    "AtomicBool", "AtomicUsize", "AtomicIsize", "AtomicU8", "AtomicU16", "AtomicU32", "AtomicU64",
    "AtomicI8", "AtomicI16", "AtomicI32", "AtomicI64",
];
const ATOMIC_METHODS: [&str; 13] = [
    "load", "store", "swap", "fetch_add", "fetch_sub", "fetch_and", "fetch_or", "fetch_xor",
    "fetch_max", "fetch_min", "fetch_update", "compare_exchange", "compare_exchange_weak",
];
/// Container methods that mutate the guarded value when called through a
/// guard-rooted chain. Deliberately curated: read-only accessors must not
/// make every lock acquisition look like a protocol-relevant write.
const MUTATING_METHODS: [&str; 15] = [
    "push", "push_back", "push_front", "pop", "pop_back", "pop_front", "insert", "remove",
    "clear", "take", "replace", "drain", "extend", "truncate", "swap_remove",
];
/// Assignment operators as the lexer emits them (compound ops that the
/// lexer splits, like `&=`, cannot appear as single tokens).
const ASSIGN_OPS: [&str; 8] = ["=", "+=", "-=", "*=", "/=", "%=", "<<=", ">>="];
const WAIT_METHODS: [&str; 2] = ["wait", "wait_timeout"];
const RECV_METHODS: [&str; 3] = ["recv", "recv_timeout", "try_recv"];
const LOAD_ORDERINGS_OK: [&str; 2] = ["Acquire", "SeqCst"];
const STORE_ORDERINGS_OK: [&str; 2] = ["Release", "SeqCst"];
const RMW_ORDERINGS_OK: [&str; 4] = ["Acquire", "Release", "AcqRel", "SeqCst"];

/// Field-name → owner tables for the sync primitives, built from every
/// non-test struct's field table (items pass).
pub struct ConcTables {
    mutex_owners: HashMap<String, Vec<String>>,
    rwlock_fields: HashSet<String>,
    condvar_fields: HashSet<String>,
    condvar_structs: HashSet<String>,
    /// field -> [(struct, ty, file_idx, decl_line)]
    atomic_owners: HashMap<String, Vec<(String, String, usize, usize)>>,
}

impl ConcTables {
    pub fn new(model: &CrateModel) -> ConcTables {
        let mut t = ConcTables {
            mutex_owners: HashMap::new(),
            rwlock_fields: HashSet::new(),
            condvar_fields: HashSet::new(),
            condvar_structs: HashSet::new(),
            atomic_owners: HashMap::new(),
        };
        for (fi, f) in model.files.iter().enumerate() {
            for st in &f.structs {
                if st.is_test {
                    continue;
                }
                for (fname, fline, fty) in &st.fields {
                    if LOCK_TYPES.contains(&fty.as_str()) {
                        t.mutex_owners.entry(fname.clone()).or_default().push(st.name.clone());
                        if fty == "RwLock" {
                            t.rwlock_fields.insert(fname.clone());
                        }
                    } else if fty == "Condvar" {
                        t.condvar_fields.insert(fname.clone());
                        t.condvar_structs.insert(st.name.clone());
                    } else if ATOMIC_TYPES.contains(&fty.as_str()) {
                        t.atomic_owners
                            .entry(fname.clone())
                            .or_default()
                            .push((st.name.clone(), fty.clone(), fi, *fline));
                    }
                }
            }
        }
        for v in t.mutex_owners.values_mut() {
            v.sort();
        }
        t
    }

    /// `Struct.field` when the receiver token is a lock field of exactly
    /// one struct, else the bare receiver token (local guards).
    fn lock_identity(&self, recv: &str) -> String {
        let owners: BTreeSet<&str> = self
            .mutex_owners
            .get(recv)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default();
        if owners.len() == 1 {
            format!("{}.{recv}", owners.iter().next().unwrap())
        } else {
            recv.to_string()
        }
    }

    /// `(identity, ty, file_idx, decl_line)` when the receiver is an atomic
    /// field of exactly one struct, else None.
    fn atomic_field(&self, recv: &str) -> Option<(String, String, usize, usize)> {
        let owners = self.atomic_owners.get(recv)?;
        let structs: HashSet<&str> = owners.iter().map(|o| o.0.as_str()).collect();
        if structs.len() == 1 {
            let (st, ty, fi, ln) = &owners[0];
            Some((format!("{st}.{recv}"), ty.clone(), *fi, *ln))
        } else {
            None
        }
    }
}

/// Index of the first token of the statement containing token `i`.
fn stmt_start(toks: &[Tok], i: usize, lo: usize) -> usize {
    let mut j = i;
    while j > lo {
        if matches!(toks[j - 1].text.as_str(), ";" | "{" | "}") {
            return j;
        }
        j -= 1;
    }
    lo
}

/// `i` at an opening bracket: index of its matching closer.
fn close_delim(toks: &[Tok], i: usize, end: usize) -> usize {
    let mut depth = 0i32;
    let mut j = i;
    while j < end {
        match toks[j].text.as_str() {
            "(" | "[" | "{" => depth += 1,
            ")" | "]" | "}" => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    end - 1
}

/// Walk a postfix chain (`.field`, `.method(..)`, `[..]`, `?`) starting at
/// token `j`. Returns `(end_idx, mutated)`: mutated when the chain calls a
/// MUTATING_METHODS name or (after at least one `.`) lands on an assignment
/// operator — i.e. it writes through whatever the chain is rooted in.
fn chain_walk(toks: &[Tok], mut j: usize, end: usize, mut saw_dot: bool) -> (usize, bool) {
    let mut mutated = false;
    while j < end {
        let t = toks[j].text.as_str();
        if t == "." {
            saw_dot = true;
            j += 1;
            if j < end && toks[j].text != "(" && toks[j].text != "[" {
                let name = toks[j].text.clone();
                j += 1;
                if j < end && toks[j].text == "(" {
                    if MUTATING_METHODS.contains(&name.as_str()) {
                        mutated = true;
                    }
                    j = close_delim(toks, j, end) + 1;
                }
            }
            continue;
        }
        if t == "[" {
            j = close_delim(toks, j, end) + 1;
            continue;
        }
        if t == "?" {
            j += 1;
            continue;
        }
        break;
    }
    if saw_dot && j < end && ASSIGN_OPS.contains(&toks[j].text.as_str()) {
        mutated = true;
    }
    (j, mutated)
}

/// Guard variable a lock acquisition at token `i` is let-bound to, or None
/// for a temporary guard (held only for its statement).
fn guard_binding(toks: &[Tok], i: usize, lo: usize) -> Option<String> {
    let b = stmt_start(toks, i, lo);
    let mut j = b;
    while j < i {
        if toks[j].text == "let" {
            let mut k = j + 1;
            if k < i && toks[k].text == "mut" {
                k += 1;
            }
            if k < i && tok_is_ident(&toks[k].text) && toks[k].text != "_" {
                return Some(toks[k].text.clone());
            }
            return None;
        }
        j += 1;
    }
    None
}

/// Token index where the guard acquired at `i` dies: a same-depth
/// `drop(guard)`, the enclosing block's close for let-bound guards, or the
/// statement end for temporaries. Conditional (deeper-nested) drops do not
/// cut the range — the guard is still held on the fall-through path.
fn guard_live_end(toks: &[Tok], i: usize, end: usize, guard: Option<&str>) -> usize {
    let mut depth = 0i32;
    let mut j = i;
    while j < end {
        let t = toks[j].text.as_str();
        match t {
            "(" | "[" | "{" => depth += 1,
            ")" | "]" | "}" => {
                if depth == 0 {
                    return j;
                }
                depth -= 1;
            }
            _ if depth == 0 => match guard {
                None => {
                    if t == ";" {
                        return j;
                    }
                }
                Some(g) => {
                    if t == "drop" && j + 2 < end && toks[j + 1].text == "(" && toks[j + 2].text == g
                    {
                        return j;
                    }
                }
            },
            _ => {}
        }
        j += 1;
    }
    end
}

/// Token ranges of every `loop`/`while`/`for` body in the fn.
fn loop_ranges(toks: &[Tok], start: usize, end: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = start;
    while i < end {
        if matches!(toks[i].text.as_str(), "loop" | "while" | "for") {
            let mut depth = 0i32;
            let mut j = i + 1;
            while j < end {
                let t = toks[j].text.as_str();
                if t == "(" || t == "[" {
                    depth += 1;
                } else if t == ")" || t == "]" {
                    depth -= 1;
                } else if t == "{" && depth == 0 {
                    out.push((j, close_delim(toks, j, end)));
                    break;
                }
                j += 1;
            }
        }
        i += 1;
    }
    out
}

/// One lock acquisition site inside a fn body.
pub struct Acquisition {
    pub ident: String,
    pub line: usize,
    pub idx: usize,
    pub guard: Option<String>,
    pub live_end: usize,
    pub mutated: bool,
    pub mut_line: usize,
}

/// One condvar wait site: (method, line, guard arg, in_loop, rebound).
pub type WaitSite = (String, usize, String, bool, bool);

/// Per-function concurrency summary (one instance per non-test fn).
#[derive(Default)]
pub struct FnConcurrency {
    pub acquisitions: Vec<Acquisition>,
    pub waits: Vec<WaitSite>,
    pub has_notify: bool,
}

pub fn summarize_fn(toks: &[Tok], f: &FnItem, tables: &ConcTables) -> FnConcurrency {
    let (start, end) = f.body;
    let mut summary = FnConcurrency::default();
    let loops = loop_ranges(toks, start, end);
    // guard var -> (live_end, acquisition index)
    let mut guards: HashMap<String, (usize, usize)> = HashMap::new();
    let mut i = start;
    while i < end {
        let t = toks[i].text.as_str();
        let ln = toks[i].line;
        let prev = if i > 0 { toks[i - 1].text.as_str() } else { "" };
        let nxt = if i + 1 < end { toks[i + 1].text.as_str() } else { "" };
        if t == "notify_one" || t == "notify_all" {
            summary.has_notify = true;
        } else if prev == "." && nxt == "(" && i >= 2 {
            let recv = toks[i - 2].text.clone();
            let is_lock = t == "lock"
                || ((t == "read" || t == "write") && tables.rwlock_fields.contains(recv.as_str()));
            if is_lock && tok_is_ident(&recv) {
                let ident = tables.lock_identity(&recv);
                let guard = guard_binding(toks, i, start);
                let live_end = guard_live_end(toks, i + 1, end, guard.as_deref());
                // Temporary guards: a mutating postfix chain hanging off the
                // lock call itself (`x.lock().unwrap().field = v`).
                let close = close_delim(toks, i + 1, end);
                let (_, chain_mut) = chain_walk(toks, close + 1, end, true);
                let mut_line = if chain_mut { ln } else { 0 };
                summary.acquisitions.push(Acquisition {
                    ident,
                    line: ln,
                    idx: i,
                    guard: guard.clone(),
                    live_end,
                    mutated: chain_mut,
                    mut_line,
                });
                if let Some(g) = guard {
                    guards.insert(g, (live_end, summary.acquisitions.len() - 1));
                }
            } else if WAIT_METHODS.contains(&t) && tables.condvar_fields.contains(recv.as_str()) {
                let arg = if i + 2 < end { toks[i + 2].text.clone() } else { String::new() };
                let in_loop = loops.iter().any(|&(lo, hi)| lo < i && i < hi);
                let b = stmt_start(toks, i, start);
                let mut j = b;
                if j < i && toks[j].text == "let" {
                    j += 1;
                }
                if j < i && toks[j].text == "mut" {
                    j += 1;
                }
                let rebound = tok_is_ident(&arg)
                    && j + 1 < i
                    && toks[j].text == arg
                    && toks[j + 1].text == "=";
                summary.waits.push((t.to_string(), ln, arg, in_loop, rebound));
            }
        } else if tok_is_ident(t) && prev != "." {
            // Guard-rooted use: `*g op=`, `g.path = v`, `g.container.push(..)`.
            if let Some(&(live_end, ai)) = guards.get(t) {
                if i < live_end && !summary.acquisitions[ai].mutated {
                    if prev == "*" && ASSIGN_OPS.contains(&nxt) {
                        summary.acquisitions[ai].mutated = true;
                        summary.acquisitions[ai].mut_line = ln;
                    } else {
                        let (_, chain_mut) = chain_walk(toks, i + 1, end, false);
                        if chain_mut {
                            summary.acquisitions[ai].mutated = true;
                            summary.acquisitions[ai].mut_line = ln;
                        }
                    }
                }
            }
        }
        i += 1;
    }
    summary
}

/// Lines of `spawn(..)` calls whose JoinHandle is discarded (the spawn
/// chain is a bare statement: not bound, not an argument, not returned).
fn spawn_sites(toks: &[Tok], f: &FnItem) -> Vec<usize> {
    let mut out = Vec::new();
    let (start, end) = f.body;
    let mut i = start;
    while i < end {
        if toks[i].text == "spawn" && i + 1 < end && toks[i + 1].text == "(" {
            let close = close_delim(toks, i + 1, end);
            let (j, _) = chain_walk(toks, close + 1, end, false);
            if j < end && toks[j].text == ";" {
                let b = stmt_start(toks, i, start);
                let mut depth = 0i32;
                let mut used = false;
                for k in b..i {
                    match toks[k].text.as_str() {
                        "(" | "[" => depth += 1,
                        ")" | "]" => depth -= 1,
                        "let" | "=" | "return" | "=>" => {
                            used = true;
                            break;
                        }
                        _ => {}
                    }
                }
                if depth > 0 {
                    used = true;
                }
                if !used {
                    out.push(toks[i].line);
                }
            }
        }
        i += 1;
    }
    out
}

/// Lines where a channel receive is `.unwrap()`/`.expect()`-ed.
fn recv_unwrap_sites(toks: &[Tok], f: &FnItem) -> Vec<usize> {
    let mut out = Vec::new();
    let (start, end) = f.body;
    let mut i = start;
    while i < end {
        if RECV_METHODS.contains(&toks[i].text.as_str())
            && i > 0
            && toks[i - 1].text == "."
            && i + 1 < end
            && toks[i + 1].text == "("
        {
            let close = close_delim(toks, i + 1, end);
            if close + 2 < end
                && toks[close + 1].text == "."
                && matches!(toks[close + 2].text.as_str(), "unwrap" | "expect")
            {
                out.push(toks[i].line);
            }
        }
        i += 1;
    }
    out
}

/// The four whole-program concurrency rules over every non-test fn.
pub fn lint_concurrency(model: &CrateModel, sink: &mut Sink) {
    let tables = ConcTables::new(model);
    let (nodes, index) = build_call_index(model);
    let mut summaries: HashMap<(usize, usize), FnConcurrency> = HashMap::new();
    for &(fi, gi) in &nodes {
        let f = &model.files[fi];
        summaries.insert((fi, gi), summarize_fn(&f.toks, &f.fns[gi], &tables));
    }

    // Resolved call edges with token positions (for held-guard call ranges).
    type Calls = Vec<(usize, usize, Vec<(usize, usize)>)>;
    let mut calls_of: HashMap<(usize, usize), Calls> = HashMap::new();
    let mut edges_of: HashMap<(usize, usize), Vec<(usize, usize)>> = HashMap::new();
    for &(fi, gi) in &nodes {
        let f = &model.files[fi];
        let fnm = &f.fns[gi];
        let mut calls = Calls::new();
        let mut targets = Vec::new();
        for e in call_edges(&f.toks, fnm) {
            let resolved = resolve_call(model, &index, &e, fnm.ctx.as_deref());
            if !resolved.is_empty() {
                targets.extend(resolved.iter().copied());
                calls.push((e.idx, e.line, resolved));
            }
        }
        calls_of.insert((fi, gi), calls);
        edges_of.insert((fi, gi), targets);
    }

    // Transitive lock sets: direct acquisitions closed over call edges.
    let mut trans: HashMap<(usize, usize), BTreeSet<String>> = nodes
        .iter()
        .map(|&n| {
            (n, summaries[&n].acquisitions.iter().map(|a| a.ident.clone()).collect())
        })
        .collect();
    let mut changed = true;
    while changed {
        changed = false;
        for &n in &nodes {
            let mut extra: Vec<String> = Vec::new();
            for callee in &edges_of[&n] {
                for l in &trans[callee] {
                    if !trans[&n].contains(l) {
                        extra.push(l.clone());
                    }
                }
            }
            if !extra.is_empty() {
                let set = trans.get_mut(&n).unwrap();
                set.extend(extra);
                changed = true;
            }
        }
    }

    // --- lock-order: acquisition-order graph + cycle detection ------------
    let mut edge_sites: HashMap<(String, String), (usize, usize)> = HashMap::new();
    for &(fi, gi) in &nodes {
        let summary = &summaries[&(fi, gi)];
        for a in &summary.acquisitions {
            for o in &summary.acquisitions {
                if o.idx > a.idx && o.idx < a.live_end {
                    edge_sites
                        .entry((a.ident.clone(), o.ident.clone()))
                        .or_insert((fi, o.line));
                }
            }
            for (c_ti, c_ln, resolved) in &calls_of[&(fi, gi)] {
                if *c_ti > a.idx && *c_ti < a.live_end {
                    for callee in resolved {
                        for callee_lock in &trans[callee] {
                            edge_sites
                                .entry((a.ident.clone(), callee_lock.clone()))
                                .or_insert((fi, *c_ln));
                        }
                    }
                }
            }
        }
    }
    let mut adj: HashMap<&str, HashSet<&str>> = HashMap::new();
    for (held, acquired) in edge_sites.keys() {
        adj.entry(held).or_default().insert(acquired);
    }
    let reaches = |src: &str, dst: &str| -> bool {
        let mut seen: HashSet<&str> = HashSet::new();
        seen.insert(src);
        let mut stack = vec![src];
        while let Some(u) = stack.pop() {
            if u == dst {
                return true;
            }
            if let Some(vs) = adj.get(u) {
                for &v in vs {
                    if seen.insert(v) {
                        stack.push(v);
                    }
                }
            }
        }
        false
    };
    let mut ordered: Vec<(&(String, String), &(usize, usize))> = edge_sites.iter().collect();
    ordered.sort_by(|a, b| {
        (&model.files[a.1 .0].rel, a.1 .1, &a.0 .0, &a.0 .1)
            .cmp(&(&model.files[b.1 .0].rel, b.1 .1, &b.0 .0, &b.0 .1))
    });
    for ((held, acquired), &(fi, ln)) in ordered {
        if reaches(acquired, held) {
            let f = &model.files[fi];
            sink.emit(
                &f.scanned,
                &f.rel,
                ln,
                "lock-order",
                format!(
                    "acquiring `{acquired}` while holding `{held}` closes an \
                     acquisition-order cycle (`{acquired}` is also held when `{held}` \
                     is taken elsewhere) — potential deadlock"
                ),
                false,
            );
        }
    }

    // --- condvar-discipline + atomic-ordering + channel-lifecycle ---------
    // identity -> (decl site, load orderings, store orderings)
    type AtomicSlot = ((usize, usize), BTreeSet<String>, BTreeSet<String>);
    let mut atomic_usage: BTreeMap<String, AtomicSlot> = BTreeMap::new();
    for &(fi, gi) in &nodes {
        let f = &model.files[fi];
        let fnm = &f.fns[gi];
        let s = &f.scanned;
        let summary = &summaries[&(fi, gi)];

        for (meth, ln, _arg, in_loop, rebound) in &summary.waits {
            if !(*in_loop && *rebound) {
                sink.emit(
                    s,
                    &f.rel,
                    *ln,
                    "condvar-discipline",
                    format!(
                        "`Condvar::{meth}` outside a predicate loop: the guard must be \
                         rebound from the wait result inside a `loop`/`while` that \
                         re-checks the predicate under the lock"
                    ),
                    false,
                );
            }
        }
        let mut reported: HashSet<&str> = HashSet::new();
        for a in &summary.acquisitions {
            let struct_name = a.ident.split_once('.').map(|(st, _)| st);
            if a.mutated
                && struct_name.is_some_and(|st| tables.condvar_structs.contains(st))
                && !summary.has_notify
                && !reported.contains(a.ident.as_str())
            {
                reported.insert(&a.ident);
                sink.emit(
                    s,
                    &f.rel,
                    a.mut_line,
                    "condvar-discipline",
                    format!(
                        "state guarded by `{}` is mutated but `{}` never calls \
                         `notify_one`/`notify_all` on the paired condvar — a \
                         waiter can miss this update",
                        a.ident,
                        fn_label(fnm)
                    ),
                    false,
                );
            }
        }

        let (start, end) = fnm.body;
        let mut i = start;
        while i < end {
            let t = f.toks[i].text.as_str();
            if ATOMIC_METHODS.contains(&t)
                && i > 0
                && f.toks[i - 1].text == "."
                && i + 1 < end
                && f.toks[i + 1].text == "("
            {
                let close = close_delim(&f.toks, i + 1, end);
                let mut orderings: Vec<(String, usize)> = Vec::new();
                for j in (i + 2)..close.saturating_sub(1) {
                    if f.toks[j].text == "Ordering" && f.toks[j + 1].text == "::" {
                        orderings.push((f.toks[j + 2].text.clone(), f.toks[j + 2].line));
                    }
                }
                if !orderings.is_empty() {
                    let recv =
                        if i >= 2 { f.toks[i - 2].text.clone() } else { String::new() };
                    let info = if tok_is_ident(&recv) {
                        tables.atomic_field(&recv)
                    } else {
                        None
                    };
                    for (ordv, oln) in &orderings {
                        if let Some((ident, _, _, _)) =
                            info.as_ref().filter(|x| x.1 == "AtomicBool")
                        {
                            let ok = (t == "load" && LOAD_ORDERINGS_OK.contains(&ordv.as_str()))
                                || (t == "store" && STORE_ORDERINGS_OK.contains(&ordv.as_str()))
                                || (t != "load"
                                    && t != "store"
                                    && RMW_ORDERINGS_OK.contains(&ordv.as_str()));
                            if !ok {
                                sink.emit(
                                    s,
                                    &f.rel,
                                    *oln,
                                    "atomic-ordering",
                                    format!(
                                        "flag `{ident}` {t} uses `Ordering::{ordv}` — \
                                         load/store flag pairs must use \
                                         Acquire/Release or SeqCst"
                                    ),
                                    false,
                                );
                            }
                        } else if ordv == "Relaxed" {
                            let label = info
                                .as_ref()
                                .map(|x| x.0.clone())
                                .unwrap_or_else(|| recv.clone());
                            sink.emit(
                                s,
                                &f.rel,
                                *oln,
                                "atomic-ordering",
                                format!(
                                    "`Ordering::Relaxed` on `{label}` — Relaxed is only \
                                     legal on sites annotated as monotonic \
                                     counters/gauges (lint-ok with the monotonicity \
                                     argument), otherwise upgrade the ordering"
                                ),
                                false,
                            );
                        }
                    }
                    if let Some((ident, _, dfi, dln)) = &info {
                        if t == "load" || t == "store" {
                            let slot = atomic_usage.entry(ident.clone()).or_insert((
                                (*dfi, *dln),
                                BTreeSet::new(),
                                BTreeSet::new(),
                            ));
                            for (ordv, _) in &orderings {
                                if t == "load" {
                                    slot.1.insert(ordv.clone());
                                } else {
                                    slot.2.insert(ordv.clone());
                                }
                            }
                        }
                    }
                }
            }
            i += 1;
        }

        for ln in spawn_sites(&f.toks, fnm) {
            sink.emit(
                s,
                &f.rel,
                ln,
                "channel-lifecycle",
                "spawned thread's JoinHandle is discarded — a `Sender` moved \
                 into a detached thread can outlive teardown and hang its \
                 receiver; bind and join the handle (or lint-ok with the \
                 teardown story)"
                    .into(),
                false,
            );
        }
        for ln in recv_unwrap_sites(&f.toks, fnm) {
            sink.emit(
                s,
                &f.rel,
                ln,
                "channel-lifecycle",
                "channel receive result is unwrapped — a dropped sender \
                 becomes a teardown panic; match the `Err` and exit the \
                 receive loop instead"
                    .into(),
                false,
            );
        }
    }

    // Per-field ordering consistency (flag pairs must not mix disciplines).
    for (ident, ((fi, ln), loads, stores)) in &atomic_usage {
        let f = &model.files[*fi];
        for (cls, set) in [("load", loads), ("store", stores)] {
            if set.len() > 1 {
                let listed: Vec<&str> = set.iter().map(String::as_str).collect();
                sink.emit(
                    &f.scanned,
                    &f.rel,
                    *ln,
                    "atomic-ordering",
                    format!(
                        "atomic field `{ident}` mixes {cls} orderings {{{}}} — pick \
                         one discipline per field",
                        listed.join(", ")
                    ),
                    false,
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lints::{lint_source, Finding};

    fn rules_of(f: &[Finding]) -> Vec<&'static str> {
        f.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn abba_lock_inversion_flagged() {
        let src = "struct Pair { a: Mutex<u32>, b: Mutex<u32> }\n\
                   impl Pair {\n\
                     fn fwd(&self) { let ga = self.a.lock().unwrap(); let gb = self.b.lock().unwrap(); drop(gb); drop(ga); }\n\
                     fn bwd(&self) { let gb = self.b.lock().unwrap(); let ga = self.a.lock().unwrap(); drop(ga); drop(gb); }\n\
                   }\n";
        let f = lint_source("rust/src/util/x.rs", src);
        assert_eq!(rules_of(&f), vec!["lock-order", "lock-order"]);
        assert!(f[0].msg.contains("Pair.a") && f[0].msg.contains("Pair.b"));
    }

    #[test]
    fn consistent_lock_order_clean_including_call_edges() {
        let src = "struct Pair { a: Mutex<u32>, b: Mutex<u32> }\n\
                   impl Pair {\n\
                     fn fwd(&self) { let ga = self.a.lock().unwrap(); let gb = self.b.lock().unwrap(); drop(gb); drop(ga); }\n\
                     fn via(&self) { let ga = self.a.lock().unwrap(); self.tail(); drop(ga); }\n\
                     fn tail(&self) { let gb = self.b.lock().unwrap(); drop(gb); }\n\
                   }\n";
        assert!(lint_source("rust/src/util/x.rs", src).is_empty());
    }

    #[test]
    fn transitive_lock_inversion_via_callee_flagged() {
        let src = "struct Pair { a: Mutex<u32>, b: Mutex<u32> }\n\
                   impl Pair {\n\
                     fn fwd(&self) { let ga = self.a.lock().unwrap(); self.tail_b(); drop(ga); }\n\
                     fn bwd(&self) { let gb = self.b.lock().unwrap(); self.tail_a(); drop(gb); }\n\
                     fn tail_a(&self) { let g = self.a.lock().unwrap(); drop(g); }\n\
                     fn tail_b(&self) { let g = self.b.lock().unwrap(); drop(g); }\n\
                   }\n";
        let f = lint_source("rust/src/util/x.rs", src);
        assert_eq!(rules_of(&f), vec!["lock-order", "lock-order"]);
    }

    #[test]
    fn bare_wait_and_missing_notify_flagged() {
        let src = "struct Gate { open: Mutex<bool>, cv: Condvar }\n\
                   impl Gate {\n\
                     fn wait_open(&self) { let g = self.open.lock().unwrap(); let g = self.cv.wait(g).unwrap(); drop(g); }\n\
                     fn open_up(&self) { *self.open.lock().unwrap() = true; }\n\
                   }\n";
        let f = lint_source("rust/src/util/x.rs", src);
        assert_eq!(rules_of(&f), vec!["condvar-discipline", "condvar-discipline"]);
    }

    #[test]
    fn predicate_loop_with_notify_clean() {
        let src = "struct Gate { open: Mutex<bool>, cv: Condvar }\n\
                   impl Gate {\n\
                     fn wait_open(&self) { let mut g = self.open.lock().unwrap(); while !*g { g = self.cv.wait(g).unwrap(); } }\n\
                     fn open_up(&self) { *self.open.lock().unwrap() = true; self.cv.notify_all(); }\n\
                   }\n";
        assert!(lint_source("rust/src/util/x.rs", src).is_empty());
    }

    #[test]
    fn relaxed_flag_pair_flagged() {
        let src = "struct S { stop: AtomicBool }\n\
                   impl S {\n\
                     fn req(&self) { self.stop.store(true, Ordering::Relaxed); }\n\
                     fn chk(&self) -> bool { self.stop.load(Ordering::Relaxed) }\n\
                   }\n";
        let f = lint_source("rust/src/util/x.rs", src);
        assert_eq!(rules_of(&f), vec!["atomic-ordering", "atomic-ordering"]);
        assert!(f[0].msg.contains("S.stop"));
    }

    #[test]
    fn release_acquire_flag_and_annotated_counter_clean() {
        let src = "struct S { stop: AtomicBool, n: AtomicU64 }\n\
                   impl S {\n\
                     fn req(&self) { self.stop.store(true, Ordering::Release); }\n\
                     fn chk(&self) -> bool {\n\
                       // lint-ok(atomic-ordering): monotonic counter\n\
                       self.n.fetch_add(1, Ordering::Relaxed);\n\
                       self.stop.load(Ordering::Acquire)\n\
                     }\n\
                   }\n";
        assert!(lint_source("rust/src/util/x.rs", src).is_empty());
    }

    #[test]
    fn mixed_orderings_per_field_flagged_at_decl() {
        let src = "struct S { stop: AtomicBool }\n\
                   impl S {\n\
                     fn a(&self) -> bool { self.stop.load(Ordering::Acquire) }\n\
                     fn b(&self) -> bool { self.stop.load(Ordering::SeqCst) }\n\
                   }\n";
        let f = lint_source("rust/src/util/x.rs", src);
        assert_eq!(rules_of(&f), vec!["atomic-ordering"]);
        assert_eq!(f[0].line, 1); // decl line of `stop`
        assert!(f[0].msg.contains("mixes load orderings"));
    }

    #[test]
    fn discarded_spawn_and_recv_unwrap_flagged() {
        let src = "fn start(rx: Receiver<u32>) {\n\
                     std::thread::spawn(move || {\n\
                       loop { let _v = rx.recv().unwrap(); }\n\
                     });\n\
                   }\n";
        let f = lint_source("rust/src/util/x.rs", src);
        assert_eq!(rules_of(&f), vec!["channel-lifecycle", "channel-lifecycle"]);
    }

    #[test]
    fn bound_joined_spawn_and_matched_recv_clean() {
        let src = "fn run(rx: Receiver<u32>) {\n\
                     let h = std::thread::spawn(move || loop {\n\
                       match rx.recv() { Ok(_) => {} Err(_) => break }\n\
                     });\n\
                     h.join().unwrap();\n\
                   }\n";
        assert!(lint_source("rust/src/util/x.rs", src).is_empty());
    }

    #[test]
    fn test_fns_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n  fn t(rx: Receiver<u32>) { rx.recv().unwrap(); }\n}\n";
        assert!(lint_source("rust/src/util/x.rs", src).is_empty());
    }
}
