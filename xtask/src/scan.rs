//! A minimal Rust source scanner for the structural lints.
//!
//! This is deliberately **not** a parser: the lints are token-level
//! properties, so all we need is a masked view of the source where comment
//! and string/char-literal bodies are blanked out (preserving line
//! structure), plus the comment text per line (for `// SAFETY:` and
//! `// cast-ok:` detection) and the line ranges covered by
//! `#[cfg(test)]`-gated items (tests may panic/cast freely) and by
//! `#[cfg(.. feature = "simd" ..)]`-gated items (the only lines where
//! `core::arch` intrinsics are legal — see the `simd-gating` lint).
//!
//! The masking rules mirror `rustc`'s lexer closely enough for this
//! codebase: line comments, nested block comments, string literals with
//! escapes, raw strings `r#".."#`, byte strings, char literals, and
//! lifetimes (`'a` is not a char literal). Anything the scanner cannot
//! classify is left in place, which can only produce *extra* findings —
//! the lint fails safe.

use std::collections::HashMap;

/// Masked view of one source file.
pub struct Scanned {
    /// Source with comment/string/char bodies replaced by spaces.
    /// Newlines are preserved, so line numbers match the original.
    pub masked: String,
    /// Comment text (line + block) keyed by the 1-based line it starts on.
    pub comments: HashMap<usize, String>,
    /// `masked`, split into lines (index 0 = line 1).
    pub lines: Vec<String>,
    /// `test_lines[i]` is true when 1-based line `i + 1` is inside a
    /// `#[cfg(test)]`-gated item.
    pub test_lines: Vec<bool>,
    /// `simd_lines[i]` is true when 1-based line `i + 1` is inside an item
    /// gated by a `#[cfg(...)]` attribute naming the `simd` feature (e.g.
    /// `#[cfg(all(feature = "simd", target_arch = "x86_64"))]`). Used by the
    /// `simd-gating` lint: `core::arch` intrinsics may only appear on such
    /// lines.
    pub simd_lines: Vec<bool>,
}

pub fn scan(src: &str) -> Scanned {
    let bytes = src.as_bytes();
    let n = bytes.len();
    let mut out: Vec<u8> = Vec::with_capacity(n);
    let mut comments: HashMap<usize, String> = HashMap::new();
    let mut line = 1usize;
    let mut i = 0usize;

    // Push a masked byte: newlines survive, everything else becomes space.
    fn mask_into(out: &mut Vec<u8>, line: &mut usize, bytes: &[u8]) {
        for &b in bytes {
            if b == b'\n' {
                out.push(b'\n');
                *line += 1;
            } else {
                out.push(b' ');
            }
        }
    }

    while i < n {
        let c = bytes[i];
        let nx = if i + 1 < n { bytes[i + 1] } else { 0 };
        match c {
            b'\n' => {
                out.push(b'\n');
                line += 1;
                i += 1;
            }
            b'/' if nx == b'/' => {
                let mut j = i;
                while j < n && bytes[j] != b'\n' {
                    j += 1;
                }
                let text = String::from_utf8_lossy(&bytes[i..j]).into_owned();
                comments.entry(line).or_default().push_str(&text);
                mask_into(&mut out, &mut line, &bytes[i..j]);
                i = j;
            }
            b'/' if nx == b'*' => {
                let start_line = line;
                let mut depth = 1usize;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if bytes[j] == b'/' && j + 1 < n && bytes[j + 1] == b'*' {
                        depth += 1;
                        j += 2;
                    } else if bytes[j] == b'*' && j + 1 < n && bytes[j + 1] == b'/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                let text = String::from_utf8_lossy(&bytes[i..j]).into_owned();
                comments.entry(start_line).or_default().push_str(&text);
                mask_into(&mut out, &mut line, &bytes[i..j]);
                i = j;
            }
            b'r' | b'b' if is_raw_string_start(bytes, i) => {
                let j = skip_raw_string(bytes, i);
                mask_into(&mut out, &mut line, &bytes[i..j]);
                i = j;
            }
            b'"' => {
                let j = skip_string(bytes, i);
                mask_into(&mut out, &mut line, &bytes[i..j]);
                i = j;
            }
            b'b' if nx == b'"' => {
                let j = skip_string(bytes, i + 1);
                mask_into(&mut out, &mut line, &bytes[i..j]);
                i = j;
            }
            b'\'' => {
                if nx == b'\\' {
                    // Escaped char literal: '\n', '\x7f', '\u{...}'.
                    let mut j = i + 2;
                    while j < n && bytes[j] != b'\'' && bytes[j] != b'\n' {
                        j += 1;
                    }
                    if j < n && bytes[j] == b'\'' {
                        j += 1;
                    }
                    mask_into(&mut out, &mut line, &bytes[i..j]);
                    i = j;
                } else if i + 2 < n && bytes[i + 2] == b'\'' {
                    // Plain char literal 'x'.
                    out.extend_from_slice(b"   ");
                    i += 3;
                } else {
                    // Lifetime: mask just the quote.
                    out.push(b' ');
                    i += 1;
                }
            }
            _ => {
                // Keep ASCII code bytes; blank multi-byte UTF-8 (it only
                // appears in identifiers-adjacent prose in this repo, never
                // in tokens the lints inspect).
                out.push(if c < 0x80 { c } else { b' ' });
                i += 1;
            }
        }
    }

    let masked = String::from_utf8(out).expect("masked output is ASCII + newlines");
    let lines: Vec<String> = masked.split('\n').map(|s| s.to_string()).collect();
    let test_lines = mark_test_lines(&masked, lines.len());
    let simd_lines = mark_simd_lines(src, &masked, lines.len());
    Scanned {
        masked,
        comments,
        lines,
        test_lines,
        simd_lines,
    }
}

fn is_raw_string_start(bytes: &[u8], i: usize) -> bool {
    let mut j = i;
    if bytes[j] == b'b' {
        j += 1;
    }
    if j >= bytes.len() || bytes[j] != b'r' {
        return false;
    }
    j += 1;
    while j < bytes.len() && bytes[j] == b'#' {
        j += 1;
    }
    j < bytes.len() && bytes[j] == b'"'
}

fn skip_raw_string(bytes: &[u8], i: usize) -> usize {
    let mut j = i;
    if bytes[j] == b'b' {
        j += 1;
    }
    j += 1; // 'r'
    let mut hashes = 0usize;
    while j < bytes.len() && bytes[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    j += 1; // opening quote
    loop {
        if j >= bytes.len() {
            return bytes.len();
        }
        if bytes[j] == b'"' {
            let mut h = 0usize;
            while j + 1 + h < bytes.len() && bytes[j + 1 + h] == b'#' && h < hashes {
                h += 1;
            }
            if h == hashes {
                return j + 1 + hashes;
            }
        }
        j += 1;
    }
}

fn skip_string(bytes: &[u8], i: usize) -> usize {
    let mut j = i + 1;
    while j < bytes.len() {
        match bytes[j] {
            b'\\' => j += 2,
            b'"' => return j + 1,
            _ => j += 1,
        }
    }
    bytes.len()
}

fn line_of(masked: &str, byte_off: usize) -> usize {
    masked.as_bytes()[..byte_off].iter().filter(|&&b| b == b'\n').count() + 1
}

/// Mark every line covered by a `#[cfg(test)]` item (attribute through the
/// matching close brace of the item body).
fn mark_test_lines(masked: &str, n_lines: usize) -> Vec<bool> {
    let mut marks = vec![false; n_lines + 2];
    let bytes = masked.as_bytes();
    let needle = b"#[cfg(test)]";
    let mut from = 0usize;
    while let Some(pos) = find_from(bytes, needle, from) {
        from = pos + needle.len();
        // Scan forward to the item's opening brace; a `;` first means a
        // body-less item (e.g. `mod tests;`) — nothing to mark.
        let mut j = from;
        let mut open = None;
        while j < bytes.len() {
            match bytes[j] {
                b'{' => {
                    open = Some(j);
                    break;
                }
                b';' => break,
                _ => j += 1,
            }
        }
        let Some(open) = open else { continue };
        let close = match_brace(bytes, open);
        let l0 = line_of(masked, pos);
        let l1 = line_of(masked, close.min(bytes.len().saturating_sub(1)));
        for l in l0..=l1.min(n_lines) {
            marks[l] = true;
        }
    }
    // Convert from 1-based line numbers to a 0-based vec.
    (1..=n_lines)
        .map(|l| marks.get(l).copied().unwrap_or(false))
        .collect()
}

/// Mark every line covered by an item whose `#[cfg(...)]` attribute names
/// the `simd` feature (attribute line through the matching close brace, or
/// through the `;` for body-less items like a gated `use`).
///
/// The attribute *content* must be read from the **raw** source: masking
/// blanks string-literal bodies, so `"simd"` inside
/// `#[cfg(feature = "simd")]` is spaces in `masked`. Masking preserves byte
/// length, so offsets found structurally in `masked` index the same
/// characters in `raw`. This is a token-level check — it asks only that
/// `feature` and `simd` appear inside the cfg predicate, so a pathological
/// `not(feature = "simd")` gate would satisfy it; the lint is a guard-rail
/// against *ungated* intrinsics, not a cfg evaluator.
fn mark_simd_lines(raw: &str, masked: &str, n_lines: usize) -> Vec<bool> {
    let mut marks = vec![false; n_lines + 2];
    let bytes = masked.as_bytes();
    let raw_bytes = raw.as_bytes();
    let needle = b"#[cfg(";
    let mut from = 0usize;
    while let Some(pos) = find_from(bytes, needle, from) {
        from = pos + needle.len();
        let open_paren = pos + needle.len() - 1;
        let close_paren = match_paren(bytes, open_paren);
        let pred = &raw_bytes[open_paren..close_paren.min(raw_bytes.len())];
        if find_from(pred, b"feature", 0).is_none() || find_from(pred, b"simd", 0).is_none() {
            continue;
        }
        // Forward from the end of the attribute to the item's opening brace;
        // a `;` first means a body-less gated item (`use`, `static .. = ..;`
        // without braces) — mark through the `;` line instead.
        let mut j = close_paren;
        let mut open = None;
        let mut semi = None;
        while j < bytes.len() {
            match bytes[j] {
                b'{' => {
                    open = Some(j);
                    break;
                }
                b';' => {
                    semi = Some(j);
                    break;
                }
                _ => j += 1,
            }
        }
        let end = match (open, semi) {
            (Some(open), _) => match_brace(bytes, open),
            (None, Some(semi)) => semi,
            (None, None) => continue,
        };
        let l0 = line_of(masked, pos);
        let l1 = line_of(masked, end.min(bytes.len().saturating_sub(1)));
        for l in l0..=l1.min(n_lines) {
            marks[l] = true;
        }
    }
    (1..=n_lines)
        .map(|l| marks.get(l).copied().unwrap_or(false))
        .collect()
}

fn find_from(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || from >= hay.len() {
        return None;
    }
    hay[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|p| p + from)
}

/// Byte offset of the paren matching the one at `open` (best effort: end of
/// file when unbalanced — fails safe by over-marking the predicate span).
fn match_paren(bytes: &[u8], open: usize) -> usize {
    let mut depth = 0usize;
    let mut j = open;
    while j < bytes.len() {
        match bytes[j] {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    bytes.len().saturating_sub(1)
}

/// Byte offset of the brace matching the one at `open` (best effort: end of
/// file when unbalanced — fails safe by over-marking).
fn match_brace(bytes: &[u8], open: usize) -> usize {
    let mut depth = 0usize;
    let mut j = open;
    while j < bytes.len() {
        match bytes[j] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    bytes.len().saturating_sub(1)
}

impl Scanned {
    /// 1-based inclusive line spans of every `fn <name>` body in the file.
    pub fn fn_spans(&self, name: &str) -> Vec<(usize, usize)> {
        let bytes = self.masked.as_bytes();
        let mut spans = Vec::new();
        let mut from = 0usize;
        while let Some(pos) = find_from(bytes, b"fn ", from) {
            from = pos + 3;
            // Word boundary before `fn`.
            if pos > 0 && is_ident(bytes[pos - 1]) {
                continue;
            }
            let mut j = pos + 3;
            while j < bytes.len() && bytes[j] == b' ' {
                j += 1;
            }
            let id_start = j;
            while j < bytes.len() && is_ident(bytes[j]) {
                j += 1;
            }
            if &bytes[id_start..j] != name.as_bytes() {
                continue;
            }
            // Forward to the body's opening brace; `;` first = trait decl.
            let mut k = j;
            let mut open = None;
            while k < bytes.len() {
                match bytes[k] {
                    b'{' => {
                        open = Some(k);
                        break;
                    }
                    b';' => break,
                    _ => k += 1,
                }
            }
            let Some(open) = open else { continue };
            let close = match_brace(bytes, open);
            spans.push((line_of(&self.masked, pos), line_of(&self.masked, close)));
        }
        spans
    }
}

pub fn is_ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_strings_and_comments() {
        let s = scan("let x = \"as usize\"; // as usize\nlet y = 1;\n");
        assert!(!s.lines[0].contains("as usize"));
        assert!(s.comments[&1].contains("as usize"));
        assert_eq!(s.lines[1], "let y = 1;");
    }

    #[test]
    fn masks_raw_strings_and_chars() {
        let s = scan("let p = r#\"unsafe { }\"#; let c = 'u'; let lt: &'a u8 = &0;\n");
        assert!(!s.masked.contains("unsafe"));
        assert!(s.masked.contains("& a u8")); // lifetime quote masked, ident kept
    }

    #[test]
    fn nested_block_comments() {
        let s = scan("/* outer /* inner */ still */ let z = 2;\n");
        assert!(s.masked.contains("let z = 2;"));
        assert!(!s.masked.contains("inner"));
    }

    #[test]
    fn cfg_test_items_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n  fn t() { x.unwrap(); }\n}\nfn live2() {}\n";
        let s = scan(src);
        assert!(!s.test_lines[0]);
        assert!(s.test_lines[1] && s.test_lines[2] && s.test_lines[3] && s.test_lines[4]);
        assert!(!s.test_lines[5]);
    }

    #[test]
    fn simd_gated_items_marked() {
        let src = "use core::arch::x86_64::*;\n\
                   #[cfg(all(feature = \"simd\", target_arch = \"x86_64\"))]\n\
                   mod avx2 {\n    use core::arch::x86_64::*;\n}\n\
                   #[cfg(all(feature = \"simd\", target_arch = \"x86_64\"))]\n\
                   pub use avx2::dot;\n\
                   #[cfg(test)]\nmod tests {}\n";
        let s = scan(src);
        // Bare use on line 1: not gated.
        assert!(!s.simd_lines[0]);
        // Attribute + mod body (lines 2-5) and body-less gated use (6-7).
        assert!(s.simd_lines[1] && s.simd_lines[2] && s.simd_lines[3] && s.simd_lines[4]);
        assert!(s.simd_lines[5] && s.simd_lines[6]);
        // `#[cfg(test)]` does not count as a simd gate.
        assert!(!s.simd_lines[7] && !s.simd_lines[8]);
    }

    #[test]
    fn fn_spans_found() {
        let src = "impl A {\n    fn pump(&self) {\n        body();\n    }\n}\nfn other() {}\n";
        let s = scan(src);
        assert_eq!(s.fn_spans("pump"), vec![(2, 4)]);
        assert_eq!(s.fn_spans("other"), vec![(6, 6)]);
        assert!(s.fn_spans("missing").is_empty());
    }
}
