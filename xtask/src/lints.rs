//! The thirteen repo-specific structural lints.
//!
//! Five are per-file rules (see DESIGN.md §9 for the full rationale):
//!
//! * `accounting-fields` — outside `rust/src/kvcache/`, the pool accounting
//!   fields `used_bytes` / `cold_bytes` / `outstanding` may only be touched
//!   through their accessor methods; any raw field access (no call parens)
//!   is flagged.
//! * `lossy-casts` — in the byte/token accounting scope (`kvcache`,
//!   `coordinator`, `server`, `config`), narrowing or signedness-changing
//!   integer `as` casts are flagged unless the line carries a
//!   `// cast-ok: <reason>` annotation.
//! * `safety-comments` — every `unsafe` block / `unsafe impl` must carry a
//!   `// SAFETY:` comment on the same line or directly above.
//! * `hot-path-panics` — no `unwrap` / `expect` / panic-family macros in
//!   the serving hot path (`batcher.rs`, `fn pump`, any `step_fused`).
//! * `simd-gating` — `core::arch` imports and `#[target_feature]` only
//!   inside `#[cfg(.. feature = "simd" ..)]`-gated items, plus a runtime
//!   `*_feature_detected!` check somewhere in the file.
//!
//! Four are whole-program rules built on the item tree / call graph
//! ([`crate::items`], [`crate::callgraph`], [`crate::units`]):
//!
//! * `hot-path-alloc` — no allocating construct (`Vec::new`, `vec!`,
//!   `format!`, `Box::new`, `.to_vec()`, `.clone()`, `.collect()`, …)
//!   transitively reachable from `Batcher::step`, any `step_fused`, or
//!   `ServingEngine::decode`, outside the `*Scratch` / `*Arena` types.
//!   Grow-only ops on existing buffers (`push`, `resize`, `extend`) are
//!   deliberately NOT markers — the scratch-arena contract is grow-only,
//!   and what this rule polices is fresh per-step heap traffic.
//! * `unit-confusion` — cross-unit `+`/`-`/comparison between
//!   `_bytes`/`_tokens`/`_pages`/`_rows`-suffixed values, unless the value
//!   flows through a blessed converter (`bytes_for_tokens`, `token_bytes`,
//!   `cache_bytes_per_token`) or a `_per_` ratio factor.
//! * `sendptr-escape` — every `SendPtr` construction outside its home
//!   module must sit in a fn that derives disjoint ranges (parallel_for /
//!   chunks / split_at idiom) and be named by a test in
//!   `rust/tests/miri_kernels.rs`.
//! * `dispatch-parity-drift` — every `KernelDispatch` fn-pointer field
//!   needs a scalar arm, a feature-gated SIMD arm, a parity test naming
//!   it, and a DESIGN.md §5e table row.
//!
//! Four are concurrency-protocol rules built on the per-fn concurrency
//! summaries ([`crate::concurrency`]) propagated over the call graph:
//!
//! * `lock-order` — `Mutex`/`RwLock` acquisition-order cycles (potential
//!   ABBA deadlock), including orders established through call edges.
//! * `condvar-discipline` — `Condvar::wait` outside a guard-rebinding
//!   predicate loop; mutation of condvar-guarded state with no notify.
//! * `atomic-ordering` — `Ordering::Relaxed` outside annotated monotonic
//!   counters; mis-ordered `AtomicBool` flag pairs; per-field ordering
//!   drift between sites.
//! * `channel-lifecycle` — `spawn(..)` with a discarded `JoinHandle`;
//!   `recv()`-family results piped straight into `unwrap`/`expect`.
//!
//! `#[cfg(test)]`-gated items are exempt from `lossy-casts`,
//! `hot-path-panics`, and the whole-program rules (tests may allocate and
//! assert freely); `safety-comments`, `accounting-fields`, and
//! `simd-gating` apply everywhere. Any finding can be suppressed with an
//! inline `// lint-ok(<rule>): <reason>` on the finding line or the line
//! above; suppressions are counted and reported, never silent.
//!
//! Keep in lockstep with `tools/lint_mirror.py`.

use crate::callgraph::{
    fn_label, reachable_from_hot_roots, CrateModel, AUX_DESIGN, AUX_MIRI, AUX_PARITY,
};
use crate::lexer::{lex, skip_angle, tok_is_ident, Tok};
use crate::scan::{is_ident, scan, Scanned};
use crate::units::UnitScanner;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

pub const RULES: [&str; 13] = [
    "accounting-fields",
    "lossy-casts",
    "safety-comments",
    "hot-path-panics",
    "simd-gating",
    "hot-path-alloc",
    "unit-confusion",
    "sendptr-escape",
    "dispatch-parity-drift",
    "lock-order",
    "condvar-discipline",
    "atomic-ordering",
    "channel-lifecycle",
];

/// `// lint-ok(<rule>): <reason>` on the line or the line above.
pub fn lint_ok(s: &Scanned, line: usize, rule: &str) -> bool {
    let needle = format!("lint-ok({rule})");
    for ln in [line, line.saturating_sub(1)] {
        if ln >= 1 && s.comments.get(&ln).is_some_and(|c| c.contains(&needle)) {
            return true;
        }
    }
    false
}

/// Finding sink with `lint-ok` suppression + counting.
#[derive(Default)]
pub struct Sink {
    pub findings: Vec<Finding>,
    pub suppressed: usize,
}

impl Sink {
    pub fn emit(
        &mut self,
        s: &Scanned,
        rel: &str,
        line: usize,
        rule: &'static str,
        msg: String,
        force_ok: bool,
    ) {
        if force_ok || lint_ok(s, line, rule) {
            self.suppressed += 1;
            return;
        }
        self.findings.push(Finding {
            file: rel.to_string(),
            line,
            rule,
            msg,
        });
    }
}

// --- shared helpers --------------------------------------------------------

const ACCOUNTING_FIELDS: [&str; 3] = ["used_bytes", "cold_bytes", "outstanding"];

/// Integer targets that need a `cast-ok` justification in accounting scope.
/// `u64` (the accounting-native width) and floats are always allowed.
const FLAGGED_CASTS: [&str; 11] = [
    "u8", "u16", "u32", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
];

/// Directories whose integer casts are accounting-relevant.
const CAST_SCOPE: [&str; 4] = [
    "rust/src/kvcache/",
    "rust/src/coordinator/",
    "rust/src/server/",
    "rust/src/config/",
];

const PANIC_MACROS: [&str; 4] = ["panic!", "unreachable!", "todo!", "unimplemented!"];

/// Tokens whose presence on a line marks it as intrinsic use. Deliberately
/// *not* matched: `std::arch::is_x86_feature_detected!` — the detection
/// macro path contains neither `core::arch` nor an arch-module segment, so
/// the guard itself never trips the rule.
const INTRINSIC_MARKERS: [&str; 4] = [
    "core::arch",
    "std::arch::x86_64",
    "std::arch::aarch64",
    "#[target_feature",
];

/// Occurrences of `word` in `line` with identifier boundaries. A boundary is
/// only required on a side whose edge character is itself an identifier
/// character (so `.unwrap` accepts `x.unwrap` but rejects `.unwrapx`).
fn word_positions(line: &str, word: &str) -> Vec<usize> {
    let bytes = line.as_bytes();
    let wb = word.as_bytes();
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = line[from..].find(word) {
        let p = p + from;
        from = p + 1;
        let pre_ok = !is_ident(wb[0]) || p == 0 || !is_ident(bytes[p - 1]);
        let end = p + word.len();
        let post_ok = !is_ident(wb[wb.len() - 1]) || end >= bytes.len() || !is_ident(bytes[end]);
        if pre_ok && post_ok {
            out.push(p);
        }
    }
    out
}

fn next_non_space(line: &str, from: usize) -> Option<char> {
    line[from..].chars().find(|c| !c.is_whitespace())
}

fn in_test(s: &Scanned, line: usize) -> bool {
    line >= 1 && s.test_lines.get(line - 1).copied().unwrap_or(false)
}

fn comment_on(s: &Scanned, line: usize, needle: &str) -> bool {
    s.comments.get(&line).is_some_and(|c| c.contains(needle))
}

// --- Rule 1: accounting-fields --------------------------------------------

fn lint_accounting_fields(rel: &str, s: &Scanned, sink: &mut Sink) {
    if rel.starts_with("rust/src/kvcache/") {
        return;
    }
    for (i, line) in s.lines.iter().enumerate() {
        for field in ACCOUNTING_FIELDS {
            let dotted = format!(".{field}");
            for p in word_positions(line, &dotted) {
                // `.used_bytes()` is the accessor — allowed. `.used_bytes`
                // bare (read, write, or arithmetic) is the violation.
                if next_non_space(line, p + dotted.len()) == Some('(') {
                    continue;
                }
                sink.emit(
                    s,
                    rel,
                    i + 1,
                    "accounting-fields",
                    format!(
                        "raw access to accounting field `{field}` outside kvcache \
                         (use the accessor / counter API audited by verify_accounting)"
                    ),
                    false,
                );
            }
        }
    }
}

// --- Rule 2: lossy-casts ---------------------------------------------------

fn lint_lossy_casts(rel: &str, s: &Scanned, sink: &mut Sink) {
    if !CAST_SCOPE.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    for (i, line) in s.lines.iter().enumerate() {
        let ln = i + 1;
        if in_test(s, ln) {
            continue;
        }
        for p in word_positions(line, "as") {
            let rest = &line[p + 2..];
            let ty: String = rest
                .trim_start()
                .chars()
                .take_while(|&c| c.is_ascii() && is_ident(c as u8))
                .collect();
            if !FLAGGED_CASTS.contains(&ty.as_str()) {
                continue;
            }
            if comment_on(s, ln, "cast-ok:") {
                continue;
            }
            sink.emit(
                s,
                rel,
                ln,
                "lossy-casts",
                format!(
                    "narrowing `as {ty}` in accounting path — use u64-native math, \
                     `try_from`, or justify with `// cast-ok: <reason>`"
                ),
                false,
            );
        }
    }
}

// --- Rule 3: safety-comments ----------------------------------------------

fn lint_safety_comments(rel: &str, s: &Scanned, sink: &mut Sink) {
    for (i, line) in s.lines.iter().enumerate() {
        let ln = i + 1;
        for p in word_positions(line, "unsafe") {
            let rest = line[p + "unsafe".len()..].trim_start();
            if !(rest.starts_with('{') || rest.starts_with("impl")) {
                // `unsafe fn` declarations are covered by
                // `#![deny(unsafe_op_in_unsafe_fn)]` instead.
                continue;
            }
            if comment_on(s, ln, "SAFETY:") {
                continue;
            }
            // Walk the contiguous run of comment / attribute lines directly
            // above; a code line or a blank line ends the association.
            let mut found = false;
            let mut k = ln.saturating_sub(1);
            while k >= 1 {
                if comment_on(s, k, "SAFETY:") {
                    found = true;
                    break;
                }
                let stripped = s.lines[k - 1].trim();
                if !stripped.is_empty() && !stripped.starts_with("#[") {
                    // A code line ends the walk — unless it is a wrapped
                    // statement head (`let x =`) whose unsafe block rustfmt
                    // pushed to the next line; continuation lines don't end
                    // with a statement terminator.
                    if stripped.ends_with(';')
                        || stripped.ends_with('}')
                        || stripped.ends_with('{')
                        || stripped.ends_with(')')
                    {
                        break;
                    }
                } else if stripped.is_empty() && !s.comments.contains_key(&k) {
                    break; // blank line separates any earlier comment
                }
                k -= 1;
            }
            if !found {
                sink.emit(
                    s,
                    rel,
                    ln,
                    "safety-comments",
                    "unsafe block/impl without a preceding `// SAFETY:` comment".into(),
                    false,
                );
            }
        }
    }
}

// --- Rule 4: hot-path-panics ----------------------------------------------

fn lint_hot_path_panics(rel: &str, s: &Scanned, sink: &mut Sink) {
    let mut hot: Vec<bool> = vec![false; s.lines.len()];
    if rel == "rust/src/coordinator/batcher.rs" {
        for (i, h) in hot.iter_mut().enumerate() {
            *h = !in_test(s, i + 1);
        }
    }
    if rel == "rust/src/coordinator/mod.rs" {
        for (a, b) in s.fn_spans("pump") {
            for l in a..=b.min(s.lines.len()) {
                hot[l - 1] = true;
            }
        }
    }
    // `step_fused` is hot wherever it is defined or overridden.
    for (a, b) in s.fn_spans("step_fused") {
        if in_test(s, a) {
            continue;
        }
        for l in a..=b.min(s.lines.len()) {
            hot[l - 1] = true;
        }
    }
    for (i, line) in s.lines.iter().enumerate() {
        if !hot[i] {
            continue;
        }
        for meth in ["unwrap", "expect"] {
            let dotted = format!(".{meth}");
            for p in word_positions(line, &dotted) {
                if next_non_space(line, p + dotted.len()) == Some('(') {
                    sink.emit(
                        s,
                        rel,
                        i + 1,
                        "hot-path-panics",
                        format!(
                            "`.{meth}(..)` in the serving hot path — route the error \
                             to TokenEvent::Rejected / anyhow::Result instead"
                        ),
                        false,
                    );
                }
            }
        }
        for mac in PANIC_MACROS {
            let bare = &mac[..mac.len() - 1];
            for p in word_positions(line, bare) {
                if line[p + bare.len()..].starts_with('!') {
                    sink.emit(
                        s,
                        rel,
                        i + 1,
                        "hot-path-panics",
                        format!("`{mac}` in the serving hot path"),
                        false,
                    );
                }
            }
        }
    }
}

// --- Rule 5: simd-gating ---------------------------------------------------

fn lint_simd_gating(rel: &str, s: &Scanned, sink: &mut Sink) {
    let mut any_intrinsics = false;
    for (i, line) in s.lines.iter().enumerate() {
        let Some(marker) = INTRINSIC_MARKERS.iter().find(|m| line.contains(*m)) else {
            continue;
        };
        any_intrinsics = true;
        if s.simd_lines.get(i).copied().unwrap_or(false) {
            continue;
        }
        sink.emit(
            s,
            rel,
            i + 1,
            "simd-gating",
            format!(
                "`{marker}` outside a `#[cfg(.. feature = \"simd\" ..)]`-gated item — \
                 scalar-only builds (--no-default-features, Miri) must not compile intrinsics"
            ),
            false,
        );
    }
    if any_intrinsics && !s.masked.contains("_feature_detected!") {
        sink.emit(
            s,
            rel,
            1,
            "simd-gating",
            "file uses arch intrinsics but contains no runtime `*_feature_detected!` \
             check — compiling an ISA arm must never imply executing it"
                .into(),
            false,
        );
    }
}

// --- Rule 6: hot-path-alloc ------------------------------------------------

const ALLOC_TYPES: [&str; 10] = [
    "Vec", "VecDeque", "String", "Box", "HashMap", "HashSet", "BTreeMap", "BTreeSet", "Rc", "Arc",
];
const ALLOC_TYPE_METHODS: [&str; 3] = ["new", "with_capacity", "from"];
const ALLOC_MACROS: [&str; 2] = ["vec", "format"];
const ALLOC_METHODS: [&str; 5] = ["to_vec", "to_string", "to_owned", "clone", "collect"];
const ARENA_SUFFIXES: [&str; 2] = ["Scratch", "Arena"];

fn lint_hot_path_alloc(model: &CrateModel, sink: &mut Sink) {
    let reach = reachable_from_hot_roots(model);
    let mut keys: Vec<&(usize, usize)> = reach.keys().collect();
    keys.sort();
    for &&(fi, gi) in &keys {
        let roots = &reach[&(fi, gi)];
        let f = &model.files[fi];
        let fnm = &f.fns[gi];
        if fnm
            .ctx
            .as_deref()
            .is_some_and(|c| ARENA_SUFFIXES.iter().any(|sfx| c.ends_with(sfx)))
        {
            continue; // grow-only scratch arenas are the sanctioned allocator
        }
        let s = &f.scanned;
        // Annotation on the signature line exempts the whole body.
        let fn_exempt = lint_ok(s, fnm.sig_line, "hot-path-alloc");
        let toks = &f.toks;
        let (start, end) = fnm.body;
        let roots_str = roots.join(", ");
        let mut i = start;
        while i < end {
            let t = toks[i].text.as_str();
            let ln = toks[i].line;
            let mut marker: Option<String> = None;
            if ALLOC_TYPES.contains(&t) && i + 2 < end && toks[i + 1].text == "::" {
                let mut k = i + 2;
                if toks[k].text == "<" {
                    k = skip_angle(toks, k);
                    if k < end && toks[k].text == "::" {
                        k += 1;
                    }
                }
                let m = if k < end { toks[k].text.as_str() } else { "" };
                let allowed: &[&str] = if t == "Rc" || t == "Arc" {
                    &["new"]
                } else {
                    &ALLOC_TYPE_METHODS
                };
                if allowed.contains(&m) {
                    let mut k2 = k + 1;
                    if k2 < end && toks[k2].text == "::" && k2 + 1 < end && toks[k2 + 1].text == "<"
                    {
                        k2 = skip_angle(toks, k2 + 1);
                    }
                    if k2 < end && toks[k2].text == "(" {
                        marker = Some(format!("{t}::{m}"));
                    }
                }
            } else if ALLOC_MACROS.contains(&t) && i + 1 < end && toks[i + 1].text == "!" {
                marker = Some(format!("{t}!"));
            } else if ALLOC_METHODS.contains(&t) && i > 0 && toks[i - 1].text == "." {
                let mut k = i + 1;
                if k < end && toks[k].text == "::" && k + 1 < end && toks[k + 1].text == "<" {
                    k = skip_angle(toks, k + 1);
                }
                if k < end && toks[k].text == "(" {
                    marker = Some(format!(".{t}()"));
                }
            }
            if let Some(marker) = marker {
                sink.emit(
                    s,
                    &f.rel,
                    ln,
                    "hot-path-alloc",
                    format!(
                        "allocating construct `{marker}` in `{}`, reachable from {roots_str} — the \
                         steady-state serving hot path must not allocate (grow-only \
                         scratch arenas excepted; annotate intentional cold paths with \
                         `// lint-ok(hot-path-alloc): <why>`)",
                        fn_label(fnm)
                    ),
                    fn_exempt,
                );
            }
            i += 1;
        }
    }
}

// --- Rule 7: unit-confusion ------------------------------------------------

fn lint_unit_confusion(model: &CrateModel, sink: &mut Sink) {
    for f in &model.files {
        for fnm in &f.fns {
            if fnm.is_test {
                continue;
            }
            let mut sc = UnitScanner::new(&f.toks, fnm.body.1);
            sc.scan_region(fnm.body.0, fnm.body.1);
            for c in sc.conflicts {
                sink.emit(
                    &f.scanned,
                    &f.rel,
                    c.line,
                    "unit-confusion",
                    format!(
                        "cross-unit arithmetic: `{}` {} `{}` — convert explicitly \
                         (bytes_for_tokens / token_bytes / cache_bytes_per_token) or \
                         annotate `// lint-ok(unit-confusion): <why>`",
                        c.left, c.op, c.right
                    ),
                    false,
                );
            }
        }
    }
}

// --- Rule 8: sendptr-escape ------------------------------------------------

const SENDPTR_HOME: &str = "rust/src/util/threadpool.rs";
const DISJOINT_IDIOMS: [&str; 7] = [
    "parallel_for",
    "chunks",
    "chunks_mut",
    "chunks_exact",
    "chunks_exact_mut",
    "split_at",
    "split_at_mut",
];

/// All identifier tokens of a source text (used for "does any test name
/// this fn" checks against the aux artifacts).
fn ident_set(text: &str) -> std::collections::HashSet<String> {
    lex(&scan(text).masked)
        .into_iter()
        .filter(|t| tok_is_ident(&t.text))
        .map(|t| t.text)
        .collect()
}

fn lint_sendptr_escape(model: &CrateModel, sink: &mut Sink) {
    let miri_idents = ident_set(model.aux_text(AUX_MIRI));
    for f in &model.files {
        if f.rel == SENDPTR_HOME {
            continue;
        }
        let toks = &f.toks;
        let s = &f.scanned;
        for (i, tok) in toks.iter().enumerate() {
            if tok.text != "SendPtr" || i + 1 >= toks.len() || toks[i + 1].text != "(" {
                continue;
            }
            let ln = tok.line;
            let Some(fnm) = f.fns.iter().find(|g| g.body.0 <= i && i < g.body.1) else {
                sink.emit(
                    s,
                    &f.rel,
                    ln,
                    "sendptr-escape",
                    "`SendPtr` constructed outside any function body — disjoint \
                     write ranges cannot be derived statically here"
                        .into(),
                    false,
                );
                continue;
            };
            if fnm.is_test {
                continue;
            }
            let (start, end) = fnm.body;
            let has_idiom = (start..end).any(|k| DISJOINT_IDIOMS.contains(&toks[k].text.as_str()));
            if !has_idiom {
                sink.emit(
                    s,
                    &f.rel,
                    ln,
                    "sendptr-escape",
                    format!(
                        "`SendPtr` constructed in `{}`, which derives no disjoint \
                         ranges (no parallel_for / chunks / split_at idiom in the \
                         body) — the Send/Sync contract requires provably disjoint \
                         writes",
                        fn_label(fnm)
                    ),
                    false,
                );
            }
            if !miri_idents.contains(&fnm.name) {
                sink.emit(
                    s,
                    &f.rel,
                    ln,
                    "sendptr-escape",
                    format!(
                        "`SendPtr` constructed in `{}`, but no test in {AUX_MIRI} names that \
                         function — every SendPtr kernel must run under the Miri lane",
                        fn_label(fnm)
                    ),
                    false,
                );
            }
        }
    }
}

// --- Rule 9: dispatch-parity-drift ----------------------------------------

/// Lines of the DESIGN.md section whose heading starts with the prefix,
/// through the next heading of equal-or-higher level.
pub fn design_section(design: &str, header_prefix: &str) -> String {
    let mut out = Vec::new();
    let mut collecting = false;
    for line in design.split('\n') {
        if collecting && (line.starts_with("### ") || line.starts_with("## ")) {
            break;
        }
        if line.starts_with(header_prefix) {
            collecting = true;
        }
        if collecting {
            out.push(line);
        }
    }
    out.join("\n")
}

fn contains_ident(text: &str, name: &str) -> bool {
    let bytes = text.as_bytes();
    let mut from = 0usize;
    while let Some(p) = text[from..].find(name) {
        let p = p + from;
        from = p + 1;
        let pre_ok = p == 0 || !is_ident(bytes[p - 1]);
        let end = p + name.len();
        let post_ok = end >= bytes.len() || !is_ident(bytes[end]);
        if pre_ok && post_ok {
            return true;
        }
    }
    false
}

fn lint_dispatch_parity(model: &CrateModel, sink: &mut Sink) {
    let parity_idents = ident_set(model.aux_text(AUX_PARITY));
    let design_5e = design_section(model.aux_text(AUX_DESIGN), "### §5e");
    for f in &model.files {
        for st in &f.structs {
            if st.name != "KernelDispatch" || st.is_test {
                continue;
            }
            let s = &f.scanned;
            for (fname, fline, first_ty) in &st.fields {
                if first_ty != "fn" {
                    continue;
                }
                let scalar_ok = f
                    .fns
                    .iter()
                    .any(|g| &g.name == fname && g.mods.iter().any(|m| m == "scalar"));
                let simd_ok = f.fns.iter().any(|g| &g.name == fname && g.is_simd);
                let test_named = f
                    .toks
                    .iter()
                    .any(|t| &t.text == fname && in_test(s, t.line));
                let parity_ok = parity_idents.contains(fname) || test_named;
                let design_ok = contains_ident(&design_5e, fname);
                let base = format!("`KernelDispatch::{fname}`");
                if !scalar_ok {
                    sink.emit(
                        s,
                        &f.rel,
                        *fline,
                        "dispatch-parity-drift",
                        format!(
                            "{base} has no scalar arm (`fn {fname}` in `mod scalar`) — the \
                             scalar tier is the bit-exact oracle every arm is judged \
                             against"
                        ),
                        false,
                    );
                }
                if !simd_ok {
                    sink.emit(
                        s,
                        &f.rel,
                        *fline,
                        "dispatch-parity-drift",
                        format!(
                            "{base} has no feature-gated SIMD arm (`fn {fname}` under a \
                             `#[cfg(.. feature = \"simd\" ..)]` item)"
                        ),
                        false,
                    );
                }
                if !parity_ok {
                    sink.emit(
                        s,
                        &f.rel,
                        *fline,
                        "dispatch-parity-drift",
                        format!(
                            "{base} is not named by any parity test ({AUX_PARITY} or a \
                             `#[cfg(test)]` item in the defining file)"
                        ),
                        false,
                    );
                }
                if !design_ok {
                    sink.emit(
                        s,
                        &f.rel,
                        *fline,
                        "dispatch-parity-drift",
                        format!("{base} has no DESIGN.md §5e parity-table row naming it"),
                        false,
                    );
                }
            }
        }
    }
}

// --- crate driver ----------------------------------------------------------

/// All thirteen lints over a set of `(rel, src)` files + aux artifacts.
/// Returns findings sorted by `(file, line, rule, msg)` plus the count of
/// `lint-ok`-suppressed findings.
pub fn lint_crate(
    file_pairs: &[(String, String)],
    aux: std::collections::HashMap<String, String>,
) -> (Vec<Finding>, usize) {
    let model = CrateModel::build(file_pairs, aux);
    let mut sink = Sink::default();
    for f in &model.files {
        lint_accounting_fields(&f.rel, &f.scanned, &mut sink);
        lint_lossy_casts(&f.rel, &f.scanned, &mut sink);
        lint_safety_comments(&f.rel, &f.scanned, &mut sink);
        lint_hot_path_panics(&f.rel, &f.scanned, &mut sink);
        lint_simd_gating(&f.rel, &f.scanned, &mut sink);
    }
    lint_hot_path_alloc(&model, &mut sink);
    lint_unit_confusion(&model, &mut sink);
    lint_sendptr_escape(&model, &mut sink);
    lint_dispatch_parity(&model, &mut sink);
    crate::concurrency::lint_concurrency(&model, &mut sink);
    sink.findings
        .sort_by(|a, b| (&a.file, a.line, a.rule, &a.msg).cmp(&(&b.file, b.line, b.rule, &b.msg)));
    (sink.findings, sink.suppressed)
}

/// Single-file convenience wrapper (unit tests, simple callers): no aux
/// artifacts, so the cross-artifact clauses of the whole-program rules see
/// empty test lists.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    lint_crate(
        &[(rel.to_string(), src.to_string())],
        std::collections::HashMap::new(),
    )
    .0
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn rules_of(f: &[Finding]) -> Vec<&'static str> {
        f.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn accounting_field_access_flagged_outside_kvcache() {
        let bad = "fn f(p: &mut Pool) { p.used_bytes += 1; }\n";
        let f = lint_source("rust/src/server/engine.rs", bad);
        assert_eq!(rules_of(&f), vec!["accounting-fields"]);
        let good = "fn f(p: &Pool) -> u64 { p.used_bytes() }\n";
        assert!(lint_source("rust/src/server/engine.rs", good).is_empty());
        assert!(lint_source("rust/src/kvcache/mod.rs", bad).is_empty());
    }

    #[test]
    fn narrowing_casts_flagged_in_scope_only() {
        let bad = "fn f(x: u64) -> usize { x as usize }\n";
        let f = lint_source("rust/src/kvcache/mod.rs", bad);
        assert_eq!(rules_of(&f), vec!["lossy-casts"]);
        let good = "fn f(x: usize) -> u64 { x as u64 + (1.5 as f64) as u64 }\n";
        assert!(lint_source("rust/src/kvcache/mod.rs", good).is_empty());
        let ok = "fn f(x: u64) -> usize { x as usize } // cast-ok: bounded by page_rows\n";
        assert!(lint_source("rust/src/kvcache/mod.rs", ok).is_empty());
        assert!(lint_source("rust/src/linalg/mat.rs", bad).is_empty());
        let test = "#[cfg(test)]\nmod tests {\n fn f(x: u64) -> usize { x as usize }\n}\n";
        assert!(lint_source("rust/src/kvcache/mod.rs", test).is_empty());
    }

    #[test]
    fn unsafe_requires_safety_comment() {
        let bad = "fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
        let f = lint_source("rust/src/util/x.rs", bad);
        assert_eq!(rules_of(&f), vec!["safety-comments"]);
        let good = "// SAFETY: p is valid for reads, caller contract.\nfn f(p: *const u8) -> u8 { unsafe { *p } }\n";
        assert!(lint_source("rust/src/util/x.rs", good).is_empty());
        let impl_bad = "unsafe impl<T> Send for P<T> {}\n";
        assert_eq!(
            rules_of(&lint_source("rust/src/util/x.rs", impl_bad)),
            vec!["safety-comments"]
        );
        let impl_good =
            "// SAFETY: P is only written at disjoint offsets.\nunsafe impl<T> Send for P<T> {}\n";
        assert!(lint_source("rust/src/util/x.rs", impl_good).is_empty());
    }

    #[test]
    fn hot_path_panics_flagged_in_batcher_and_step_fused() {
        let bad = "impl B { fn admit(&mut self) { self.q.pop().unwrap(); } }\n";
        let f = lint_source("rust/src/coordinator/batcher.rs", bad);
        assert_eq!(rules_of(&f), vec!["hot-path-panics"]);
        assert!(lint_source("rust/src/util/x.rs", bad).is_empty());
        let sf = "impl E { fn step_fused(&mut self) { panic!(\"boom\"); } }\n";
        assert_eq!(
            rules_of(&lint_source("rust/src/server/engine.rs", sf)),
            vec!["hot-path-panics"]
        );
        let pump = "impl R { fn pump(&mut self) { x.expect(\"y\"); } }\n";
        assert_eq!(
            rules_of(&lint_source("rust/src/coordinator/mod.rs", pump)),
            vec!["hot-path-panics"]
        );
        assert!(lint_source("rust/src/server/engine.rs", pump).is_empty());
        let test = "#[cfg(test)]\nmod tests {\n fn t() { q.pop().unwrap(); }\n}\n";
        assert!(lint_source("rust/src/coordinator/batcher.rs", test).is_empty());
    }

    #[test]
    fn ungated_intrinsics_flagged() {
        let bad = "use core::arch::x86_64::*;\nfn f() {}\n";
        let f = lint_source("rust/src/linalg/x.rs", bad);
        assert_eq!(rules_of(&f), vec!["simd-gating", "simd-gating"]);
        let good = "#[cfg(all(feature = \"simd\", target_arch = \"x86_64\"))]\n\
                    mod avx2 {\n\
                        use core::arch::x86_64::*;\n\
                        #[target_feature(enable = \"avx2\")]\n\
                        unsafe fn dot() {}\n\
                    }\n\
                    fn pick() -> bool { std::arch::is_x86_feature_detected!(\"avx2\") }\n";
        assert!(lint_source("rust/src/linalg/x.rs", good).is_empty());
        let undetected = "#[cfg(all(feature = \"simd\", target_arch = \"x86_64\"))]\n\
                          mod avx2 { use core::arch::x86_64::*; }\n";
        assert_eq!(
            rules_of(&lint_source("rust/src/linalg/x.rs", undetected)),
            vec!["simd-gating"]
        );
        let prose = "// core::arch is discussed here\nfn f() { let s = \"core::arch\"; }\n";
        assert!(lint_source("rust/src/linalg/x.rs", prose).is_empty());
    }

    #[test]
    fn hot_path_alloc_reachable_flagged() {
        let src = "impl Batcher {\n  fn step(&mut self) { helper(); }\n}\n\
                   fn helper() { let v: Vec<u32> = Vec::new(); drop(v); }\n";
        let f = lint_source("rust/src/coordinator/batcher.rs", src);
        assert_eq!(rules_of(&f), vec!["hot-path-alloc"]);
        assert!(f[0].msg.contains("Vec::new"));
        assert!(f[0].msg.contains("Batcher::step"));
        // Unreachable fn: clean.
        let cold = "fn helper() { let v: Vec<u32> = Vec::new(); drop(v); }\n";
        assert!(lint_source("rust/src/coordinator/batcher.rs", cold).is_empty());
    }

    #[test]
    fn hot_path_alloc_arena_and_annotations_exempt() {
        let arena = "impl Batcher {\n  fn step(&mut self) { self.scratch.grow(); }\n}\n\
                     struct Batcher { scratch: DecodeScratch }\nstruct DecodeScratch { n: usize }\n\
                     impl DecodeScratch {\n  fn grow(&mut self) { self.buf = Vec::new(); }\n}\n";
        assert!(lint_source("rust/src/server/engine.rs", arena).is_empty());
        let annotated = "impl Batcher {\n  fn step(&mut self) {\n    \
                         // lint-ok(hot-path-alloc): terminal event\n    \
                         let m = format!(\"x\");\n    drop(m);\n  }\n}\n";
        assert!(lint_source("rust/src/coordinator/batcher.rs", annotated).is_empty());
    }

    #[test]
    fn unit_confusion_flagged_outside_tests() {
        let src = "fn f(used_bytes: u64, max_tokens: u64) -> u64 { used_bytes + max_tokens }\n";
        let f = lint_source("rust/src/kvcache/mod.rs", src);
        assert_eq!(rules_of(&f), vec!["unit-confusion"]);
        let test = "#[cfg(test)]\nmod tests {\n fn f(a_bytes: u64, b_tokens: u64) -> u64 { a_bytes + b_tokens }\n}\n";
        assert!(lint_source("rust/src/kvcache/mod.rs", test).is_empty());
    }

    #[test]
    fn sendptr_requires_idiom_and_miri_test() {
        let src = "fn kernel(out: &mut [f32]) {\n  let p = SendPtr(out.as_mut_ptr());\n  drop(p);\n}\n";
        // No idiom + no miri aux: both findings.
        let f = lint_source("rust/src/linalg/mat.rs", src);
        assert_eq!(rules_of(&f), vec!["sendptr-escape", "sendptr-escape"]);
        // With the idiom and a miri test naming the fn: clean.
        let good = "fn kernel(out: &mut [f32]) {\n  let (lo, hi) = out.split_at_mut(1);\n  let p = SendPtr(lo.as_mut_ptr());\n  drop((p, hi));\n}\n";
        let mut aux = HashMap::new();
        aux.insert(
            crate::callgraph::AUX_MIRI.to_string(),
            "#[test]\nfn miri_kernel() { kernel(&mut []); }\n".to_string(),
        );
        let (f, _) = lint_crate(
            &[("rust/src/linalg/mat.rs".to_string(), good.to_string())],
            aux,
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn dispatch_parity_drift_fires_per_missing_artifact() {
        let src = "pub struct KernelDispatch {\n  pub dot_f32: fn(&[f32], &[f32]) -> f32,\n}\n";
        let f = lint_source("rust/src/linalg/simd.rs", src);
        // No scalar arm, no simd arm, no parity test, no DESIGN row.
        assert_eq!(f.len(), 4);
        assert!(f.iter().all(|x| x.rule == "dispatch-parity-drift"));
    }

    #[test]
    fn dispatch_parity_clean_when_all_artifacts_present() {
        let src = "pub struct KernelDispatch {\n  pub dot_f32: fn(&[f32], &[f32]) -> f32,\n}\n\
                   mod scalar {\n  pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 { s(a, b) }\n}\n\
                   #[cfg(all(feature = \"simd\", target_arch = \"x86_64\"))]\n\
                   mod avx2 {\n  pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 { v(a, b) }\n}\n";
        let mut aux = HashMap::new();
        aux.insert(
            crate::callgraph::AUX_PARITY.to_string(),
            "#[test]\nfn parity() { check(dot_f32); }\n".to_string(),
        );
        aux.insert(
            crate::callgraph::AUX_DESIGN.to_string(),
            "### §5e kernels\n\n| `dot_f32` | bitwise |\n\n### next\n".to_string(),
        );
        let (f, _) = lint_crate(
            &[("rust/src/linalg/simd.rs".to_string(), src.to_string())],
            aux,
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn suppression_is_counted() {
        let src = "impl Batcher {\n  fn step(&mut self) {\n    // lint-ok(hot-path-alloc): once\n    let v = vec![0u8; 4];\n    drop(v);\n  }\n}\n";
        let (f, suppressed) = lint_crate(
            &[(
                "rust/src/coordinator/batcher.rs".to_string(),
                src.to_string(),
            )],
            HashMap::new(),
        );
        assert!(f.is_empty());
        assert_eq!(suppressed, 1);
    }

    #[test]
    fn panic_in_string_or_comment_not_flagged() {
        let s = "fn step_fused() { let m = \"panic! not real\"; log(m); } // panic! here too\n";
        assert!(lint_source("rust/src/x.rs", s).is_empty());
    }
}
