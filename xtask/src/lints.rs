//! The five repo-specific structural lints.
//!
//! Rules (see DESIGN.md §9 for the full rationale):
//!
//! * `accounting-fields` — outside `rust/src/kvcache/`, the pool accounting
//!   fields `used_bytes` / `cold_bytes` / `outstanding` may only be touched
//!   through their accessor methods; any raw field access (no call parens)
//!   is flagged. All mutation lives behind the incremental-counter API that
//!   `KvCacheManager::verify_accounting` audits.
//! * `lossy-casts` — in the byte/token accounting scope (`kvcache`,
//!   `coordinator`, `server`, `config`), narrowing or signedness-changing
//!   integer `as` casts are flagged unless the line carries a
//!   `// cast-ok: <reason>` annotation. Widening into the accounting-native
//!   `u64` and float casts are free; kernel modules (`linalg`, `attn`,
//!   `model`, …) are outside the scope entirely — that is the float-math
//!   allowlist.
//! * `safety-comments` — every `unsafe` block / `unsafe impl` must carry a
//!   `// SAFETY:` comment stating the aliasing/lifetime argument, on the
//!   same line or in the contiguous comment/attribute run directly above.
//! * `simd-gating` — `core::arch` / `std::arch::{x86_64,aarch64}` imports
//!   and `#[target_feature]` attributes may only appear inside items gated
//!   by a `#[cfg(.. feature = "simd" ..)]` attribute, so scalar-only builds
//!   (`--no-default-features`, the Miri lane) can never reach an intrinsic;
//!   and any file using intrinsics must also contain a runtime
//!   `*_feature_detected!` check somewhere, so compiling the arm never
//!   implies executing it on a host without the ISA.
//! * `hot-path-panics` — no `unwrap` / `expect` / `panic!` /
//!   `unreachable!` / `todo!` / `unimplemented!` in the serving hot path:
//!   all of `coordinator/batcher.rs`, every `fn pump` in
//!   `coordinator/mod.rs`, and every `fn step_fused`. Errors must flow to
//!   `TokenEvent::Rejected` (or an `anyhow::Result`), never abort the
//!   scheduler.
//!
//! `#[cfg(test)]`-gated items are exempt from `lossy-casts` and
//! `hot-path-panics` (tests may assert freely); `safety-comments` and
//! `accounting-fields` apply everywhere.

use crate::scan::{is_ident, scan, Scanned};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

pub const RULES: [&str; 5] = [
    "accounting-fields",
    "lossy-casts",
    "safety-comments",
    "hot-path-panics",
    "simd-gating",
];

const ACCOUNTING_FIELDS: [&str; 3] = ["used_bytes", "cold_bytes", "outstanding"];

/// Integer targets that need a `cast-ok` justification in accounting scope.
/// `u64` (the accounting-native width) and floats are always allowed.
const FLAGGED_CASTS: [&str; 11] = [
    "u8", "u16", "u32", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
];

/// Directories whose integer casts are accounting-relevant.
const CAST_SCOPE: [&str; 4] = [
    "rust/src/kvcache/",
    "rust/src/coordinator/",
    "rust/src/server/",
    "rust/src/config/",
];

/// Lint one file. `rel` is the repo-relative path (it selects per-path
/// rules); `src` is the file contents.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let s = scan(src);
    let mut out = Vec::new();
    lint_accounting_fields(rel, &s, &mut out);
    lint_lossy_casts(rel, &s, &mut out);
    lint_safety_comments(&s, &mut out);
    lint_hot_path_panics(rel, &s, &mut out);
    lint_simd_gating(&s, &mut out);
    out.sort_by_key(|f| f.line);
    out
}

fn in_test(s: &Scanned, line: usize) -> bool {
    s.test_lines.get(line - 1).copied().unwrap_or(false)
}

fn comment_on(s: &Scanned, line: usize, needle: &str) -> bool {
    s.comments.get(&line).is_some_and(|c| c.contains(needle))
}

/// Occurrences of `word` in `line` with identifier boundaries. A boundary is
/// only required on a side whose edge character is itself an identifier
/// character (so `.unwrap` accepts `x.unwrap` but rejects `.unwrapx`).
fn word_positions(line: &str, word: &str) -> Vec<usize> {
    let bytes = line.as_bytes();
    let wb = word.as_bytes();
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = line[from..].find(word) {
        let p = p + from;
        from = p + 1;
        let pre_ok = !is_ident(wb[0]) || p == 0 || !is_ident(bytes[p - 1]);
        let end = p + word.len();
        let post_ok = !is_ident(wb[wb.len() - 1]) || end >= bytes.len() || !is_ident(bytes[end]);
        if pre_ok && post_ok {
            out.push(p);
        }
    }
    out
}

fn next_non_space(line: &str, from: usize) -> Option<char> {
    line[from..].chars().find(|c| !c.is_whitespace())
}

// --- Rule 1: accounting-fields --------------------------------------------

fn lint_accounting_fields(rel: &str, s: &Scanned, out: &mut Vec<Finding>) {
    if rel.starts_with("rust/src/kvcache/") {
        return;
    }
    for (i, line) in s.lines.iter().enumerate() {
        for field in ACCOUNTING_FIELDS {
            let dotted = format!(".{field}");
            for p in word_positions(line, &dotted) {
                // `.used_bytes()` is the accessor — allowed. `.used_bytes`
                // bare (read, write, or arithmetic) is the violation.
                if next_non_space(line, p + dotted.len()) == Some('(') {
                    continue;
                }
                out.push(Finding {
                    line: i + 1,
                    rule: "accounting-fields",
                    msg: format!(
                        "raw access to accounting field `{field}` outside kvcache \
                         (use the accessor / counter API audited by verify_accounting)"
                    ),
                });
            }
        }
    }
}

// --- Rule 2: lossy-casts ---------------------------------------------------

fn lint_lossy_casts(rel: &str, s: &Scanned, out: &mut Vec<Finding>) {
    if !CAST_SCOPE.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    for (i, line) in s.lines.iter().enumerate() {
        let ln = i + 1;
        if in_test(s, ln) {
            continue;
        }
        for p in word_positions(line, "as") {
            let rest = &line[p + 2..];
            let ty: String = rest
                .trim_start()
                .chars()
                .take_while(|&c| is_ident(c as u8))
                .collect();
            if !FLAGGED_CASTS.contains(&ty.as_str()) {
                continue;
            }
            if comment_on(s, ln, "cast-ok:") {
                continue;
            }
            out.push(Finding {
                line: ln,
                rule: "lossy-casts",
                msg: format!(
                    "narrowing `as {ty}` in accounting path — use u64-native math, \
                     `try_from`, or justify with `// cast-ok: <reason>`"
                ),
            });
        }
    }
}

// --- Rule 3: safety-comments ----------------------------------------------

fn lint_safety_comments(s: &Scanned, out: &mut Vec<Finding>) {
    for (i, line) in s.lines.iter().enumerate() {
        let ln = i + 1;
        for p in word_positions(line, "unsafe") {
            let rest = line[p + "unsafe".len()..].trim_start();
            if !(rest.starts_with('{') || rest.starts_with("impl")) {
                // `unsafe fn` declarations are covered by
                // `#![deny(unsafe_op_in_unsafe_fn)]` instead.
                continue;
            }
            if comment_on(s, ln, "SAFETY:") {
                continue;
            }
            // Walk the contiguous run of comment / attribute lines directly
            // above; a code line or a blank line ends the association.
            let mut found = false;
            let mut k = ln.saturating_sub(1);
            while k >= 1 {
                if comment_on(s, k, "SAFETY:") {
                    found = true;
                    break;
                }
                let stripped = s.lines[k - 1].trim();
                if !stripped.is_empty() && !stripped.starts_with("#[") {
                    // A code line ends the walk — unless it is a wrapped
                    // statement head (`let x =`) whose unsafe block rustfmt
                    // pushed to the next line; continuation lines don't end
                    // with a statement terminator.
                    if stripped.ends_with(';')
                        || stripped.ends_with('}')
                        || stripped.ends_with('{')
                        || stripped.ends_with(')')
                    {
                        break;
                    }
                } else if stripped.is_empty() && !s.comments.contains_key(&k) {
                    break; // blank line separates any earlier comment
                }
                k -= 1;
            }
            if !found {
                out.push(Finding {
                    line: ln,
                    rule: "safety-comments",
                    msg: "unsafe block/impl without a preceding `// SAFETY:` comment".into(),
                });
            }
        }
    }
}

// --- Rule 4: hot-path-panics ----------------------------------------------

const PANIC_MACROS: [&str; 4] = ["panic!", "unreachable!", "todo!", "unimplemented!"];

fn lint_hot_path_panics(rel: &str, s: &Scanned, out: &mut Vec<Finding>) {
    let mut hot: Vec<bool> = vec![false; s.lines.len()];
    if rel == "rust/src/coordinator/batcher.rs" {
        for (i, h) in hot.iter_mut().enumerate() {
            *h = !in_test(s, i + 1);
        }
    }
    if rel == "rust/src/coordinator/mod.rs" {
        for (a, b) in s.fn_spans("pump") {
            for l in a..=b.min(s.lines.len()) {
                hot[l - 1] = true;
            }
        }
    }
    // `step_fused` is hot wherever it is defined or overridden.
    for (a, b) in s.fn_spans("step_fused") {
        if in_test(s, a) {
            continue;
        }
        for l in a..=b.min(s.lines.len()) {
            hot[l - 1] = true;
        }
    }
    for (i, line) in s.lines.iter().enumerate() {
        if !hot[i] {
            continue;
        }
        for meth in ["unwrap", "expect"] {
            let dotted = format!(".{meth}");
            for p in word_positions(line, &dotted) {
                if next_non_space(line, p + dotted.len()) == Some('(') {
                    out.push(Finding {
                        line: i + 1,
                        rule: "hot-path-panics",
                        msg: format!(
                            "`.{meth}(..)` in the serving hot path — route the error \
                             to TokenEvent::Rejected / anyhow::Result instead"
                        ),
                    });
                }
            }
        }
        for mac in PANIC_MACROS {
            let bare = &mac[..mac.len() - 1];
            for p in word_positions(line, bare) {
                if line[p + bare.len()..].starts_with('!') {
                    out.push(Finding {
                        line: i + 1,
                        rule: "hot-path-panics",
                        msg: format!("`{mac}` in the serving hot path"),
                    });
                }
            }
        }
    }
}

// --- Rule 5: simd-gating ---------------------------------------------------

/// Tokens whose presence on a line marks it as intrinsic use. Deliberately
/// *not* matched: `std::arch::is_x86_feature_detected!` — the detection
/// macro path contains neither `core::arch` nor an arch-module segment, so
/// the guard itself never trips the rule.
const INTRINSIC_MARKERS: [&str; 4] = [
    "core::arch",
    "std::arch::x86_64",
    "std::arch::aarch64",
    "#[target_feature",
];

fn lint_simd_gating(s: &Scanned, out: &mut Vec<Finding>) {
    let mut any_intrinsics = false;
    for (i, line) in s.lines.iter().enumerate() {
        let ln = i + 1;
        let Some(marker) = INTRINSIC_MARKERS.iter().find(|m| line.contains(*m)) else {
            continue;
        };
        any_intrinsics = true;
        if s.simd_lines.get(i).copied().unwrap_or(false) {
            continue;
        }
        out.push(Finding {
            line: ln,
            rule: "simd-gating",
            msg: format!(
                "`{marker}` outside a `#[cfg(.. feature = \"simd\" ..)]`-gated item — \
                 scalar-only builds (--no-default-features, Miri) must not compile intrinsics"
            ),
        });
    }
    if any_intrinsics && !s.masked.contains("_feature_detected!") {
        out.push(Finding {
            line: 1,
            rule: "simd-gating",
            msg: "file uses arch intrinsics but contains no runtime `*_feature_detected!` \
                  check — compiling an ISA arm must never imply executing it"
                .into(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(f: &[Finding]) -> Vec<&'static str> {
        f.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn accounting_field_access_flagged_outside_kvcache() {
        let bad = "fn f(p: &mut Pool) { p.used_bytes += 1; }\n";
        let f = lint_source("rust/src/server/engine.rs", bad);
        assert_eq!(rules_of(&f), vec!["accounting-fields"]);
        // Accessor call is fine.
        let good = "fn f(p: &Pool) -> u64 { p.used_bytes() }\n";
        assert!(lint_source("rust/src/server/engine.rs", good).is_empty());
        // Inside kvcache the field is the implementation — allowed.
        assert!(lint_source("rust/src/kvcache/mod.rs", bad).is_empty());
    }

    #[test]
    fn narrowing_casts_flagged_in_scope_only() {
        let bad = "fn f(x: u64) -> usize { x as usize }\n";
        let f = lint_source("rust/src/kvcache/mod.rs", bad);
        assert_eq!(rules_of(&f), vec!["lossy-casts"]);
        // u64 widening and float casts are free.
        let good = "fn f(x: usize) -> u64 { x as u64 + (1.5 as f64) as u64 }\n";
        assert!(lint_source("rust/src/kvcache/mod.rs", good).is_empty());
        // cast-ok annotation silences.
        let ok = "fn f(x: u64) -> usize { x as usize } // cast-ok: bounded by page_rows\n";
        assert!(lint_source("rust/src/kvcache/mod.rs", ok).is_empty());
        // Kernel modules are out of scope (float-math allowlist).
        assert!(lint_source("rust/src/linalg/mat.rs", bad).is_empty());
        // Tests are exempt.
        let test = "#[cfg(test)]\nmod tests {\n fn f(x: u64) -> usize { x as usize }\n}\n";
        assert!(lint_source("rust/src/kvcache/mod.rs", test).is_empty());
    }

    #[test]
    fn unsafe_requires_safety_comment() {
        let bad = "fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
        let f = lint_source("rust/src/util/x.rs", bad);
        assert_eq!(rules_of(&f), vec!["safety-comments"]);
        let good = "// SAFETY: p is valid for reads, caller contract.\nfn f(p: *const u8) -> u8 { unsafe { *p } }\n";
        assert!(lint_source("rust/src/util/x.rs", good).is_empty());
        let impl_bad = "unsafe impl<T> Send for P<T> {}\n";
        assert_eq!(rules_of(&lint_source("rust/src/util/x.rs", impl_bad)), vec!["safety-comments"]);
        let impl_good = "// SAFETY: P is only written at disjoint offsets.\nunsafe impl<T> Send for P<T> {}\n";
        assert!(lint_source("rust/src/util/x.rs", impl_good).is_empty());
    }

    #[test]
    fn hot_path_panics_flagged_in_batcher_and_step_fused() {
        let bad = "impl B { fn admit(&mut self) { self.q.pop().unwrap(); } }\n";
        let f = lint_source("rust/src/coordinator/batcher.rs", bad);
        assert_eq!(rules_of(&f), vec!["hot-path-panics"]);
        // Same code outside the hot path: fine.
        assert!(lint_source("rust/src/util/x.rs", bad).is_empty());
        // step_fused is hot anywhere.
        let sf = "impl E { fn step_fused(&mut self) { panic!(\"boom\"); } }\n";
        assert_eq!(
            rules_of(&lint_source("rust/src/server/engine.rs", sf)),
            vec!["hot-path-panics"]
        );
        // pump is hot only in coordinator/mod.rs.
        let pump = "impl R { fn pump(&mut self) { x.expect(\"y\"); } }\n";
        assert_eq!(
            rules_of(&lint_source("rust/src/coordinator/mod.rs", pump)),
            vec!["hot-path-panics"]
        );
        assert!(lint_source("rust/src/server/engine.rs", pump).is_empty());
        // Tests in batcher.rs may unwrap.
        let test = "#[cfg(test)]\nmod tests {\n fn t() { q.pop().unwrap(); }\n}\n";
        assert!(lint_source("rust/src/coordinator/batcher.rs", test).is_empty());
    }

    #[test]
    fn ungated_intrinsics_flagged() {
        // Bare arch import, no cfg gate, no detection macro: both findings.
        let bad = "use core::arch::x86_64::*;\nfn f() {}\n";
        let f = lint_source("rust/src/linalg/x.rs", bad);
        assert_eq!(rules_of(&f), vec!["simd-gating", "simd-gating"]);
        // Properly gated module with a runtime check elsewhere in the file:
        // clean.
        let good = "#[cfg(all(feature = \"simd\", target_arch = \"x86_64\"))]\n\
                    mod avx2 {\n\
                        use core::arch::x86_64::*;\n\
                        #[target_feature(enable = \"avx2\")]\n\
                        unsafe fn dot() {}\n\
                    }\n\
                    fn pick() -> bool { std::arch::is_x86_feature_detected!(\"avx2\") }\n";
        assert!(lint_source("rust/src/linalg/x.rs", good).is_empty());
        // Gated but no detection macro anywhere: the file-level finding.
        let undetected = "#[cfg(all(feature = \"simd\", target_arch = \"x86_64\"))]\n\
                          mod avx2 { use core::arch::x86_64::*; }\n";
        assert_eq!(
            rules_of(&lint_source("rust/src/linalg/x.rs", undetected)),
            vec!["simd-gating"]
        );
        // Mentions in comments/strings don't count as intrinsic use.
        let prose = "// core::arch is discussed here\nfn f() { let s = \"core::arch\"; }\n";
        assert!(lint_source("rust/src/linalg/x.rs", prose).is_empty());
    }

    #[test]
    fn panic_in_string_or_comment_not_flagged() {
        let s = "fn step_fused() { let m = \"panic! not real\"; log(m); } // panic! here too\n";
        assert!(lint_source("rust/src/x.rs", s).is_empty());
    }
}
